//! Fault-recovery benchmark over the **mock backend** — no artifacts needed,
//! so it runs everywhere (including the CI smoke step).
//!
//! Drives the serving front door (HTTP → batcher → router worker) twice with
//! the same request trace: once fault-free (the goodput baseline) and once
//! against a deterministic seeded fault plan that injects ~5% transient
//! backend faults, permanently poisons the fused-step artifact, and kills
//! the worker once mid-soak. The property under test is
//! **degrade-and-recover instead of corrupt-or-hang**: every fault is either
//! absorbed (retry, quarantine reroute, supervised respawn) or surfaced as
//! an honest classified error, and whatever the stack serves is
//! bit-identical to a fault-free solo decode.
//!
//! Gates (exit non-zero on failure):
//! * every request resolves exactly once with a classified status — 200 or
//!   500, never a hang and never a silently-wrong 200,
//! * at least one injected transient fault was retried to success
//!   (`sjd_backend_retries` advanced while the request still answered 200),
//! * the poisoned fused artifact tripped its breaker
//!   (`sjd_artifact_quarantined`) and the very next requests were served by
//!   the degradation reroute (fused → plain Jacobi) — bit-exactly,
//! * the mid-soak worker kill was supervised: `sjd_worker_panics` and
//!   `sjd_worker_restarts` advanced, the in-flight request answered 500,
//!   and the fleet ended healthy (`/healthz` 200, not degraded),
//! * goodput under injected faults stays ≥ 90% of the fault-free baseline,
//! * post-recovery, per-request outputs are **bit-identical** to solo serial
//!   decodes at τ = 0 (Prop 3.2: the fixed point does not care how many
//!   retries, reroutes, or respawns the road there took).
//!
//! ```bash
//! cargo bench --bench fault_recovery            # full run (80-request soak)
//! cargo bench --bench fault_recovery -- --quick # CI smoke (40 requests)
//! ```

use anyhow::Result;
use sjd::coordinator::batcher::Batcher;
use sjd::coordinator::fault::FaultPolicy;
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::router::{Router, RouterConfig};
use sjd::coordinator::sampler::{SampleOptions, Sampler};
use sjd::coordinator::server::{Server, ServerConfig};
use sjd::metrics::Registry;
use sjd::runtime::{Backend, FaultClass};
use sjd::tensor::Pcg64;
use sjd::testkit::fault::{FaultPlan, FaultyBackend};
use sjd::testkit::mockflow::{MockLedger, MockServeBackend};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-slot artificial decode cost (per jstep/seqstep call, × batch size).
const SLOT_DELAY: Duration = Duration::from_micros(100);
/// Distinct request seeds (kept small so solo references are cached).
const SEED_SPACE: u64 = 4;
/// Plain-jstep call index at which the worker is killed: the quarantine
/// trips after 2 poisoned requests, so by index 100 several rerouted
/// (plain-Jacobi) requests have already been served — the kill lands
/// mid-soak, after the reroute is witnessed.
const KILL_INDEX: usize = 100;

fn quick() -> bool {
    std::env::args().any(|a| a == "--quick") || std::env::var("SJD_QUICK").is_ok()
}

/// τ = 0 fused decode: exercises the jstep_fuse artifact (the quarantine
/// target) on every block, with plain Jacobi as its degradation reroute.
fn opts() -> SampleOptions {
    let mut o =
        SampleOptions { policy: DecodePolicy::Fused { chunk: 4 }, ..Default::default() };
    o.jacobi.tau = 0.0;
    o
}

/// Solo serial decode of one seed at bucket 1 — the bit-exactness oracle.
fn solo_reference(seed: u64) -> Result<Vec<f32>> {
    let be = MockServeBackend::new(&[1, 2, 4], Duration::ZERO, MockLedger::new());
    let sampler = Sampler::new(&be, "mock", 1)?;
    let z = sampler.sample_prior_slots(&[seed]);
    let out = sampler.decode_tokens(z, &opts())?;
    Ok(sampler.unpatchify(&out.tokens)?[0].data().to_vec())
}

/// Append scattered transient faults over the *plain* step artifacts only
/// (`jstep_b…`, never `jstep_fuse…` — the fused role is reserved for the
/// poison rule) to `plan`. Safe to replay on every worker incarnation: the
/// retry layer absorbs each one. The explicit index-1 rule guarantees at
/// least one transient fires early no matter what the seed scatters.
fn with_transients(mut plan: FaultPlan, seed: u64, rate: f64, horizon: usize) -> FaultPlan {
    let mut rng = Pcg64::seed(seed);
    plan = plan.fail_once("jstep_b", 1, FaultClass::Transient);
    for role in ["jstep_b", "seqstep"] {
        for idx in 0..horizon {
            if rng.next_f64() < rate {
                plan = plan.fail_once(role, idx, FaultClass::Transient);
            }
        }
    }
    plan
}

/// One-shot POST; returns the raw response text.
fn post(addr: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    write!(
        s,
        "POST /generate HTTP/1.1\r\nHost: b\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut out = String::new();
    let _ = s.read_to_string(&mut out);
    out
}

fn get(addr: &str, path: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: b\r\nConnection: close\r\n\r\n").unwrap();
    let mut out = String::new();
    let _ = s.read_to_string(&mut out);
    out
}

fn status(resp: &str) -> u16 {
    resp.split(' ').nth(1).and_then(|s| s.parse().ok()).unwrap_or(0)
}

struct Stack {
    registry: Registry,
    batcher: Batcher,
    router: Router,
    stop: Arc<AtomicBool>,
    server_thread: std::thread::JoinHandle<anyhow::Result<()>>,
    addr: &'static str,
}

fn start_stack<B, F>(addr: &'static str, fault: FaultPolicy, factory: F) -> Result<Stack>
where
    B: Backend,
    F: Fn(usize) -> Result<B> + Send + Clone + 'static,
{
    let registry = Registry::new();
    let batcher = Batcher::new(4, Duration::from_millis(2));
    batcher.bind_metrics(&registry);
    let router = Router::start_with(
        RouterConfig {
            artifacts_dir: "mock".into(),
            model: "mock".into(),
            buckets: Vec::new(),
            workers: 1,
            options: opts(),
            pipeline_depth: 1,
            stage_threads: 0,
            refill: false,
            tuner: None,
            warm_cap: 0,
            governor: None,
            fault,
            replicas: 1,
            devices: 1,
        },
        batcher.clone(),
        registry.clone(),
        factory,
    )?;
    let server = Server::with_config(
        addr,
        batcher.clone(),
        registry.clone(),
        ServerConfig { conn_threads: 8, fleet: Some(router.fleet()), ..Default::default() },
    );
    let stop = server.stop_flag();
    let server_thread = std::thread::spawn(move || server.run());
    for _ in 0..100 {
        if TcpStream::connect(addr).is_ok() {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    Ok(Stack { registry, batcher, router, stop, server_thread, addr })
}

impl Stack {
    fn counter(&self, name: &str) -> u64 {
        self.registry.counter(name).get()
    }

    fn shutdown(self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        let _ = self.server_thread.join();
        self.router.shutdown();
    }
}

/// Sequential request trace: returns the per-request status codes. Each
/// request either answers or trips the 60 s read timeout (status 0 → the
/// exactly-once gate fails), so a hang can never pass.
fn drive(stack: &Stack, n: usize) -> Vec<u16> {
    (0..n)
        .map(|i| {
            let body = format!("{{\"n\": 1, \"seed\": {}}}", i as u64 % SEED_SPACE);
            status(&post(stack.addr, &body))
        })
        .collect()
}

/// Direct-submission bit-exactness probe: every seed decoded through the
/// live stack must match its solo reference byte-for-byte.
fn assert_bit_exact(stack: &Stack, solo: &[Vec<f32>], phase: &str) -> Result<()> {
    for (seed, want) in solo.iter().enumerate() {
        let img = stack
            .batcher
            .submit(9000 + seed as u64, seed as u64)
            .map_err(|e| anyhow::anyhow!("{phase}: submit: {e}"))?
            .wait()
            .map_err(|e| anyhow::anyhow!("{phase}: decode: {e}"))?;
        if img.data() != &want[..] {
            anyhow::bail!("{phase}: seed {seed} output differs from solo decode");
        }
    }
    Ok(())
}

fn main() -> Result<()> {
    let soak_n = if quick() { 40 } else { 80 };
    println!(
        "=== fault_recovery: {soak_n}-request soak, ~5% transient faults + poisoned fused \
         artifact + one worker kill (mock backend) ==="
    );

    let solo: Vec<Vec<f32>> = (0..SEED_SPACE).map(solo_reference).collect::<Result<_>>()?;

    // --- Phase 1: fault-free goodput baseline. ---------------------------
    let ledger = MockLedger::new();
    let base = start_stack("127.0.0.1:8547", FaultPolicy::default(), {
        let ledger = ledger.clone();
        move |_| Ok(MockServeBackend::new(&[1, 2, 4], SLOT_DELAY, ledger.clone()))
    })?;
    let t0 = Instant::now();
    let base_statuses = drive(&base, soak_n);
    let base_wall = t0.elapsed();
    let base_served = base_statuses.iter().filter(|&&s| s == 200).count();
    assert_bit_exact(&base, &solo, "baseline")?;
    base.shutdown();
    anyhow::ensure!(base_served == soak_n, "fault-free baseline must serve everything");

    // --- Phase 2: the same trace against the fault plan. -----------------
    // Incarnation 0 gets transients + a permanently poisoned fused artifact
    // + a mid-soak kill; supervised respawns get the (replay-safe)
    // transient-only plan. Rule order matters: the poison rule is first, so
    // no transient rule can shadow a fused call.
    let rate = 0.05;
    let transients = with_transients(FaultPlan::none(), 0xFA57_0001, rate, 256);
    let plan0 = with_transients(
        FaultPlan::none()
            .fail_n("jstep_fuse", 0, usize::MAX, FaultClass::Poison)
            .panic_at("jstep_b", KILL_INDEX),
        0xFA57_0001,
        rate,
        256,
    );
    let fault = FaultPolicy {
        backoff_base: Duration::from_micros(200),
        backoff_cap: Duration::from_millis(2),
        quarantine_after: 2,
        probe_interval: Duration::from_secs(300),
        ..Default::default()
    };
    let incarnation = Arc::new(AtomicUsize::new(0));
    let faulty = start_stack("127.0.0.1:8548", fault, {
        let ledger = MockLedger::new();
        let plan0 = plan0.clone();
        let transients = transients.clone();
        let incarnation = incarnation.clone();
        move |_| {
            let plan = if incarnation.fetch_add(1, Ordering::SeqCst) == 0 {
                plan0.clone()
            } else {
                transients.clone()
            };
            Ok(FaultyBackend::new(
                MockServeBackend::new(&[1, 2, 4], SLOT_DELAY, ledger.clone()),
                plan,
            ))
        }
    })?;
    let t1 = Instant::now();
    let statuses = drive(&faulty, soak_n);
    let faulty_wall = t1.elapsed();

    // --- Phase 3: recovery — fleet healthy, outputs exact. ---------------
    let healthz = get(faulty.addr, "/healthz");
    let exact_after = assert_bit_exact(&faulty, &solo, "post-recovery");

    let served = statuses.iter().filter(|&&s| s == 200).count();
    let failed = statuses.iter().filter(|&&s| s == 500).count();
    let unclassified = statuses.iter().filter(|&&s| s != 200 && s != 500).count();
    let retries = faulty.counter("sjd_backend_retries");
    let quarantined = faulty.counter("sjd_artifact_quarantined");
    let panics = faulty.counter("sjd_worker_panics");
    let restarts = faulty.counter("sjd_worker_restarts");
    let degraded = faulty.router.fleet().degraded();
    let goodput = served as f64 / soak_n as f64;

    println!("\n=== summary ===");
    println!(
        "baseline {base_served}/{soak_n} in {base_wall:?} | faulty {served}/{soak_n} \
         in {faulty_wall:?} (goodput {:.1}%, {failed} honest 500s, {unclassified} \
         unclassified) | injected: {} incarnation-0 + {} transient-only | retries \
         {retries} | quarantined {quarantined} | panics {panics} restarts {restarts} \
         degraded {degraded}",
        goodput * 100.0,
        plan0.injected(),
        transients.injected(),
    );
    faulty.shutdown();

    // Exactly-once, classified: every request answered 200 or an honest 500.
    let once_ok = unclassified == 0;
    // The first two requests hit the poisoned fused artifact (no retry for
    // poison), the breaker trips, and the *next* requests are served by the
    // plain-Jacobi reroute.
    let reroute_ok =
        quarantined >= 1 && statuses[0] == 500 && statuses[1] == 500 && statuses[2] == 200;
    let retry_ok = retries >= 1;
    let respawn_ok = panics >= 1 && restarts >= 1 && !degraded;
    let health_ok = healthz.starts_with("HTTP/1.1 200");
    let goodput_ok = goodput >= 0.90 * (base_served as f64 / soak_n as f64);
    let exact_ok = exact_after.is_ok();
    if let Err(e) = &exact_after {
        eprintln!("exactness: {e:#}");
    }
    if once_ok && reroute_ok && retry_ok && respawn_ok && health_ok && goodput_ok && exact_ok {
        println!(
            "PASS: faults are retried, quarantined, or supervised away; goodput holds \
             and recovery is bit-exact"
        );
        Ok(())
    } else {
        println!(
            "FAIL: once_ok={once_ok} reroute_ok={reroute_ok} retry_ok={retry_ok} \
             respawn_ok={respawn_ok} health_ok={health_ok} goodput_ok={goodput_ok} \
             exact_ok={exact_ok}"
        );
        std::process::exit(1);
    }
}
