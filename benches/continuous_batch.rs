//! Continuous-batching benchmark over the **mock backend** — no artifacts
//! needed, so it runs everywhere (including the CI smoke step).
//!
//! Drives the batcher → router path directly (no HTTP) with a bursty
//! arrival trace shaped to expose what `serve --refill` buys: episodes of
//! a 3-request burst (covered by bucket 4, so one padded row) in which one
//! client disconnects mid-decode.
//!
//! * **held-batch** — the `refill: false` monolithic worker: the batch
//!   that formed is the batch that decodes, end to end. The padded row
//!   and the disconnected client's row ride all K = 4 blocks at bucket 4.
//! * **continuous** — `refill: true`: the cancelled slot is swept at the
//!   next block boundary, the wave compacts through the slot-remap gather
//!   and migrates to bucket 2, so blocks 1..K decode two live rows with
//!   zero padding.
//!
//! The mock's decode cost scales with the *bucket* batch size, so both the
//! padded row and the dead row burn real wall time. Gates (exit non-zero
//! on failure):
//! * every surviving request's image is **bit-identical** to its solo
//!   serial decode (τ = 0) in both configurations,
//! * continuous p99 beats held-batch p99 by ≥ 1.3×,
//! * continuous decodes strictly fewer padded slot-blocks than the
//!   held-batch baseline (whose formation pads ride all K blocks),
//! * at least one mid-flight bucket migration actually happened.
//!
//! ```bash
//! cargo bench --bench continuous_batch            # full run (32 episodes)
//! cargo bench --bench continuous_batch -- --quick # CI smoke (12 episodes)
//! ```

use anyhow::Result;
use sjd::coordinator::batcher::Batcher;
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::router::{Router, RouterConfig};
use sjd::coordinator::sampler::{SampleOptions, Sampler};
use sjd::metrics::Registry;
use sjd::testkit::mockflow::{MockLedger, MockServeBackend};
use std::time::{Duration, Instant};

/// Per-slot artificial decode cost (per jstep/seqstep call, × batch size).
const SLOT_DELAY: Duration = Duration::from_micros(300);
/// Flow blocks in `MockFlow::standard()` — the held-batch baseline decodes
/// every formation-time padded slot through all of them.
const BLOCKS: u64 = 4;
/// Distinct request seeds (kept small so solo references are cached).
const SEED_SPACE: u64 = 6;

fn quick() -> bool {
    std::env::args().any(|a| a == "--quick") || std::env::var("SJD_QUICK").is_ok()
}

fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() as f64 - 1.0) * q) as usize]
}

fn opts() -> SampleOptions {
    let mut o =
        SampleOptions { policy: DecodePolicy::UniformJacobi, ..Default::default() };
    o.jacobi.tau = 0.0;
    o
}

/// Solo serial decode of one seed at bucket 1 — the bit-exactness oracle.
fn solo_reference(seed: u64) -> Result<Vec<f32>> {
    let be = MockServeBackend::new(&[1, 2, 4], Duration::ZERO, MockLedger::new());
    let sampler = Sampler::new(&be, "mock", 1)?;
    let z = sampler.sample_prior_slots(&[seed]);
    let out = sampler.decode_tokens(z, &opts())?;
    Ok(sampler.unpatchify(&out.tokens)?[0].data().to_vec())
}

struct RunStats {
    label: &'static str,
    wall: Duration,
    ok: u64,
    latencies_ms: Vec<f64>,
    padded_slot_blocks: u64,
    migrations: u64,
    refills: u64,
}

impl RunStats {
    fn p50(&self) -> f64 {
        pct(&self.latencies_ms, 0.50)
    }

    fn p99(&self) -> f64 {
        pct(&self.latencies_ms, 0.99)
    }
}

fn run_config(
    label: &'static str,
    refill: bool,
    episodes: usize,
    solo: &[Vec<f32>],
) -> Result<RunStats> {
    let registry = Registry::new();
    let batcher = Batcher::new(4, Duration::from_millis(2));
    let ledger = MockLedger::new();
    let router = Router::start_with(
        RouterConfig {
            artifacts_dir: "mock".into(),
            model: "mock".into(),
            buckets: Vec::new(),
            workers: 1,
            options: opts(),
            pipeline_depth: 1,
            stage_threads: 0,
            refill,
            tuner: None,
            warm_cap: 0,
            governor: None,
            fault: Default::default(),
            replicas: 1,
            devices: 1,
        },
        batcher.clone(),
        registry.clone(),
        {
            let ledger = ledger.clone();
            move |_| Ok(MockServeBackend::new(&[1, 2, 4], SLOT_DELAY, ledger.clone()))
        },
    )?;

    // Bursty open-loop trace: per episode a 3-burst arrives at once, one of
    // the three disconnects ~3 ms in (mid block 0 under either config), and
    // the line goes quiet before the next burst. Each surviving request
    // gets a waiter thread so its latency is stamped the moment the slot
    // resolves, not when the trace finishes.
    let solo = std::sync::Arc::new(solo.to_vec());
    let results: std::sync::Arc<std::sync::Mutex<Vec<(f64, u8)>>> =
        std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
    const OK_EXACT: u8 = 0;
    const OK_MISMATCH: u8 = 1;
    const ERRORED: u8 = 2;
    const HUNG: u8 = 3;
    let t0 = Instant::now();
    let mut waiters = Vec::new();
    let mut cancelled = Vec::new();
    for e in 0..episodes as u64 {
        let seeds = [(3 * e) % SEED_SPACE, (3 * e + 1) % SEED_SPACE, (3 * e + 2) % SEED_SPACE];
        let handles: Vec<_> = seeds
            .iter()
            .enumerate()
            .map(|(j, &s)| batcher.submit_slot(100 * e + j as u64, s))
            .collect::<anyhow::Result<_>>()?;
        let mut handles = handles.into_iter();
        for &seed in &seeds[..2] {
            let h = handles.next().unwrap();
            let submitted = Instant::now();
            let solo = solo.clone();
            let results = results.clone();
            waiters.push(std::thread::spawn(move || {
                let status = match h.done.wait_timeout(Duration::from_secs(60)) {
                    Some(Ok(img)) if img.data() == &solo[seed as usize][..] => OK_EXACT,
                    Some(Ok(_)) => {
                        eprintln!("seed {seed}: output differs from solo decode");
                        OK_MISMATCH
                    }
                    Some(Err(msg)) => {
                        eprintln!("seed {seed}: decode error: {msg}");
                        ERRORED
                    }
                    None => {
                        eprintln!("seed {seed}: request hung");
                        HUNG
                    }
                };
                let latency = submitted.elapsed().as_secs_f64() * 1e3;
                results.lock().unwrap().push((latency, status));
            }));
        }
        std::thread::sleep(Duration::from_millis(3));
        let dropped = handles.next().unwrap();
        dropped.cancel();
        cancelled.push(dropped);
        std::thread::sleep(Duration::from_millis(47));
    }

    for w in waiters {
        let _ = w.join();
    }
    let mut hung = false;
    // Disconnected clients must still *resolve* (held-batch decodes them to
    // the end; continuous sweeps them into an error) — never hang.
    for h in &cancelled {
        if h.done.wait_timeout(Duration::from_secs(60)).is_none() {
            eprintln!("[{label}] cancelled slot hung");
            hung = true;
        }
    }
    let wall = t0.elapsed();
    router.shutdown();

    let results = results.lock().unwrap();
    let ok = results.iter().filter(|(_, s)| *s == OK_EXACT).count() as u64;
    if hung || results.iter().any(|(_, s)| *s != OK_EXACT) {
        anyhow::bail!("[{label}] per-request outputs must be bit-exact and never hang");
    }
    let mut latencies: Vec<f64> = results.iter().map(|(l, _)| *l).collect();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(RunStats {
        label,
        wall,
        ok,
        latencies_ms: latencies,
        // The held-batch worker only records formation-time padded slots;
        // each one rides all K blocks, so normalise both runs to decoded
        // padded slot-blocks.
        padded_slot_blocks: if refill {
            registry.counter("sjd_padded_slot_blocks").get()
        } else {
            registry.counter("sjd_padded_slots").get() * BLOCKS
        },
        migrations: registry.counter("sjd_bucket_migrations").get(),
        refills: registry.counter("sjd_batch_refills").get(),
    })
}

fn report(s: &RunStats, survivors: usize) {
    println!(
        "[{}] {} ok / {} survivors in {:.2}s | client ms p50 {:.1} p99 {:.1} \
         | padded slot-blocks {} | migrations {} | refills {}",
        s.label,
        s.ok,
        survivors,
        s.wall.as_secs_f64(),
        s.p50(),
        s.p99(),
        s.padded_slot_blocks,
        s.migrations,
        s.refills,
    );
}

fn main() -> Result<()> {
    let episodes = if quick() { 12 } else { 32 };
    let survivors = 2 * episodes;
    println!(
        "=== continuous_batch: {episodes} episodes of burst-3 + mid-decode disconnect \
         (mock backend) ==="
    );

    let solo: Vec<Vec<f32>> =
        (0..SEED_SPACE).map(solo_reference).collect::<Result<_>>()?;

    let held = run_config("held-batch", false, episodes, &solo)?;
    report(&held, survivors);
    let cont = run_config("continuous", true, episodes, &solo)?;
    report(&cont, survivors);

    let p99_gain = held.p99() / cont.p99().max(1e-9);
    println!("\n=== summary ===");
    println!(
        "p99 {:.1} → {:.1} ms ({p99_gain:.2}x) | padded slot-blocks {} → {} | \
         migrations {} | refills {}",
        held.p99(),
        cont.p99(),
        held.padded_slot_blocks,
        cont.padded_slot_blocks,
        cont.migrations,
        cont.refills,
    );

    let all_ok = held.ok == survivors as u64 && cont.ok == survivors as u64;
    let p99_ok = p99_gain >= 1.3;
    let pad_ok = cont.padded_slot_blocks < held.padded_slot_blocks;
    let migrated = cont.migrations >= 1;
    if all_ok && p99_ok && pad_ok && migrated {
        println!("PASS: continuous batching dominates the held-batch baseline");
        Ok(())
    } else {
        println!(
            "FAIL: all_ok={all_ok} p99_ok={p99_ok} (need ≥1.3x) pad_ok={pad_ok} \
             migrated={migrated}"
        );
        std::process::exit(1);
    }
}
