"""AOT lowering pipeline: HLO text round-trips through the XLA client with
weights intact, manifest entries are well-formed, baselines lower."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, baselines, maf, metricnet, tarflow


@pytest.fixture(scope="module")
def tiny_tf():
    cfg = tarflow.TarFlowConfig(
        name="tiny", img_hw=8, channels=3, patch=2, blocks=2, layers_per_block=1,
        model_dim=16, heads=2, noise_std=0.05, dataset="synth10",
        train_steps=1, train_batch=4, lr=1e-3)
    params = tarflow.init_params(jax.random.PRNGKey(0), cfg)
    params["out_w"] = 0.1 * jax.random.normal(jax.random.PRNGKey(1), params["out_w"].shape)
    return cfg, params


class TestHloText:
    def test_large_constants_included(self, tiny_tf):
        cfg, params = tiny_tf
        L, D = cfg.seq_len, cfg.token_dim
        lowered = jax.jit(
            lambda k, z, y, o: tarflow.block_jacobi_step(params, cfg, k, z, y, o,
                                                         use_pallas=True)
        ).lower(aot.spec((), aot.I32), aot.spec((1, L, D)), aot.spec((1, L, D)),
                aot.spec((), aot.I32))
        text = aot.to_hlo_text(lowered)
        # The elided form `constant({...})` must not appear.
        assert "constant({...})" not in text
        assert "parameter(3)" in text  # 4 entry params

    def test_text_reparses(self, tiny_tf):
        """The emitted text must parse back into an HloModule with the same
        entry signature — structure-level round-trip check. (The *numeric*
        round trip through the rust PJRT loader is covered by the rust
        integration test `artifact_pipeline`.)"""
        from jax._src.lib import xla_client as xc
        cfg, params = tiny_tf
        L, D = cfg.seq_len, cfg.token_dim

        def fn(k, z, y, o):
            return tarflow.block_jacobi_step(params, cfg, k, z, y, o, use_pallas=True)

        lowered = jax.jit(fn).lower(
            aot.spec((), aot.I32), aot.spec((1, L, D)), aot.spec((1, L, D)),
            aot.spec((), aot.I32))
        text = aot.to_hlo_text(lowered)
        mod = xc._xla.hlo_module_from_text(text)
        reparsed = mod.to_string()
        assert "f32[1,16,12]" in reparsed  # (B, L, D) entry params survive
        # Weight tensors survive with data (look for the stacked out_w shape).
        assert f"f32[{cfg.blocks},{cfg.model_dim},{2 * D}]" in reparsed


class TestArtifactWriter:
    def test_manifest_structure(self, tiny_tf, tmp_path):
        cfg, params = tiny_tf
        w = aot.ArtifactWriter(tmp_path)
        aot.lower_tarflow(w, cfg, params, [1])
        w.write_manifest()
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        names = {a["name"] for a in manifest["artifacts"]}
        assert names == {
            "tiny_fwd_b1", "tiny_block_fwd_b1", "tiny_block_jstep_b1",
            "tiny_block_jstep_win_b1", "tiny_block_jstep_fuse_b1",
            "tiny_block_jstep_win_fuse_b1", "tiny_init_proj_b1",
            "tiny_block_seqfull_b1", "tiny_block_seqstep_b1", "tiny_reverse_b1",
            "tiny_slot_gather_b1"}
        for a in manifest["artifacts"]:
            assert (tmp_path / a["file"]).exists()
            assert all("shape" in t and "dtype" in t for t in a["inputs"])
            assert all("shape" in t and "dtype" in t for t in a["outputs"])
        m = manifest["models"][0]
        assert m["seq_len"] == cfg.seq_len
        assert m["image_hwc"] == [8, 8, 3]

    def test_jstep_signature(self, tiny_tf, tmp_path):
        cfg, params = tiny_tf
        w = aot.ArtifactWriter(tmp_path)
        aot.lower_tarflow(w, cfg, params, [1])
        jstep = next(e for e in w.entries if e["name"].endswith("block_jstep_b1"))
        assert [i["dtype"] for i in jstep["inputs"]] == ["i32", "f32", "f32", "i32"]
        assert [o["shape"] for o in jstep["outputs"]] == [[1, cfg.seq_len, cfg.token_dim], [1]]

    def test_jstep_win_signature(self, tiny_tf, tmp_path):
        """The windowed GS-Jacobi step: (k, z_prev, y, off, len) → (z', resid)."""
        cfg, params = tiny_tf
        w = aot.ArtifactWriter(tmp_path)
        aot.lower_tarflow(w, cfg, params, [1])
        win = next(e for e in w.entries
                   if e["name"].endswith("block_jstep_win_b1"))
        assert [i["name"] for i in win["inputs"]] == ["k", "z_prev", "y", "off", "len"]
        assert [i["dtype"] for i in win["inputs"]] == ["i32", "f32", "f32", "i32", "i32"]
        assert [o["shape"] for o in win["outputs"]] == [[1, cfg.seq_len, cfg.token_dim], [1]]
        # Tuple-rooted (two outputs) — the untupled fast path must stay off.
        assert win["untupled_outputs"] is False

    def test_jstep_fuse_signatures(self, tiny_tf, tmp_path):
        """The fused multi-step artifacts: (k, z_prev, y, steps[, off, len])
        → (z', resid_hist[S, B]) with S = aot.JSTEP_FUSE_STEPS — the rust
        chunk scheduler reads the history cap off the output shape."""
        cfg, params = tiny_tf
        w = aot.ArtifactWriter(tmp_path)
        aot.lower_tarflow(w, cfg, params, [2])
        s = aot.JSTEP_FUSE_STEPS
        fuse = next(e for e in w.entries
                    if e["name"].endswith("block_jstep_fuse_b2"))
        assert [i["name"] for i in fuse["inputs"]] == ["k", "z_prev", "y", "steps"]
        assert [i["dtype"] for i in fuse["inputs"]] == ["i32", "f32", "f32", "i32"]
        assert [o["shape"] for o in fuse["outputs"]] == [
            [2, cfg.seq_len, cfg.token_dim], [s, 2]]
        assert fuse["untupled_outputs"] is False
        wfuse = next(e for e in w.entries
                     if e["name"].endswith("block_jstep_win_fuse_b2"))
        assert [i["name"] for i in wfuse["inputs"]] == [
            "k", "z_prev", "y", "steps", "off", "len"]
        assert [i["dtype"] for i in wfuse["inputs"]] == [
            "i32", "f32", "f32", "i32", "i32", "i32"]
        assert [o["shape"] for o in wfuse["outputs"]] == [
            [2, cfg.seq_len, cfg.token_dim], [s, 2]]
        assert wfuse["untupled_outputs"] is False


    def test_init_proj_signature(self, tiny_tf, tmp_path):
        """The speculative-init projection: (k, y) → z0, single output and
        lowered untupled — the prediction must be a chainable device leaf so
        the speculative path never round-trips through the host."""
        cfg, params = tiny_tf
        w = aot.ArtifactWriter(tmp_path)
        aot.lower_tarflow(w, cfg, params, [1])
        proj = next(e for e in w.entries if e["name"].endswith("init_proj_b1"))
        assert [i["name"] for i in proj["inputs"]] == ["k", "y"]
        assert [i["dtype"] for i in proj["inputs"]] == ["i32", "f32"]
        assert [o["shape"] for o in proj["outputs"]] == [
            [1, cfg.seq_len, cfg.token_dim]]
        assert proj["untupled_outputs"] is True

    def test_slot_gather_signature_and_semantics(self, tiny_tf, tmp_path):
        """The continuous-batching slot remap: (t[B,L,D], idx[B] i32) →
        t[idx], single output and lowered untupled so the compacted wave
        chains straight into the next block with no host round-trip. The
        gather semantics (row permutation, pads re-pointed at row 0) are
        asserted on the traced function itself."""
        cfg, params = tiny_tf
        w = aot.ArtifactWriter(tmp_path)
        aot.lower_tarflow(w, cfg, params, [2])
        g = next(e for e in w.entries if e["name"].endswith("slot_gather_b2"))
        assert [i["name"] for i in g["inputs"]] == ["t", "idx"]
        assert [i["dtype"] for i in g["inputs"]] == ["f32", "i32"]
        assert g["inputs"][1]["shape"] == [2]
        assert [o["shape"] for o in g["outputs"]] == [
            [2, cfg.seq_len, cfg.token_dim]]
        assert g["untupled_outputs"] is True
        t = jax.random.normal(jax.random.PRNGKey(3),
                              (2, cfg.seq_len, cfg.token_dim))
        out = np.asarray(jax.jit(lambda t, idx: t[idx])(
            t, jnp.asarray([1, 0], dtype=jnp.int32)))
        np.testing.assert_array_equal(out[0], np.asarray(t)[1])
        np.testing.assert_array_equal(out[1], np.asarray(t)[0])


class TestBatchBuckets:
    def test_parse_batch_sizes(self):
        assert aot.parse_batch_sizes("") is None
        assert aot.parse_batch_sizes("  ") is None
        assert aot.parse_batch_sizes("1,2,4,8") == [1, 2, 4, 8]
        # Sorted, deduped, whitespace-tolerant.
        assert aot.parse_batch_sizes("8, 1, 4, 1") == [1, 4, 8]
        with pytest.raises(ValueError):
            aot.parse_batch_sizes("1,x")
        with pytest.raises(ValueError):
            aot.parse_batch_sizes("0,2")

    def test_bucketed_lowering_emits_full_family_per_bucket(self, tiny_tf, tmp_path):
        """Every decode artifact role must exist per bucket — this is the
        completeness invariant the rust `Manifest::decode_buckets` grouping
        relies on when it marks a bucket routable."""
        cfg, params = tiny_tf
        w = aot.ArtifactWriter(tmp_path)
        aot.lower_tarflow(w, cfg, params, [1, 2])
        w.write_manifest()
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        names = {a["name"] for a in manifest["artifacts"]}
        roles = ["fwd", "block_fwd", "block_jstep", "block_jstep_win",
                 "block_jstep_fuse", "block_jstep_win_fuse", "init_proj",
                 "block_seqfull", "block_seqstep", "reverse", "slot_gather"]
        for b in (1, 2):
            for role in roles:
                assert f"tiny_{role}_b{b}" in names, f"missing {role} for bucket {b}"
        assert manifest["models"][0]["batch_sizes"] == [1, 2]
        # Shapes actually carry the bucket's batch dimension.
        by_name = {a["name"]: a for a in manifest["artifacts"]}
        for b in (1, 2):
            jstep = by_name[f"tiny_block_jstep_b{b}"]
            assert jstep["inputs"][1]["shape"] == [b, cfg.seq_len, cfg.token_dim]
            assert jstep["outputs"][1]["shape"] == [b]


class TestBaselines:
    def test_metricnet_features_shift_sensitive(self):
        cfg = metricnet.MetricNetConfig(name="m", img_hw=16)
        params = metricnet.init_params(cfg)
        a = jax.random.normal(jax.random.PRNGKey(0), (32, 16, 16, 3)) * 0.3
        b = a + 0.5
        fa = np.asarray(metricnet.features(params, a))
        fb = np.asarray(metricnet.features(params, b))
        assert fa.shape == (32, 64)
        assert np.abs(fa.mean(0) - fb.mean(0)).max() > 0.01

    def test_ddpm_eps_shape_and_t_dependence(self):
        cfg = aot.DDPM_CFG._replace(hidden=16, train_steps=1)
        params = baselines.init_ddpm_params(jax.random.PRNGKey(0), cfg)
        # Non-zero output head for the test.
        params["c4"] = 0.1 * jax.random.normal(jax.random.PRNGKey(1), params["c4"].shape)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 16, 3))
        e1 = np.asarray(baselines.eps_model(params, x, jnp.asarray(0)))
        e2 = np.asarray(baselines.eps_model(params, x, jnp.asarray(100)))
        assert e1.shape == x.shape
        assert np.abs(e1 - e2).max() > 1e-5

    def test_ddim_schedule_monotone(self):
        betas, alphas, abars = baselines.ddpm_schedule(aot.DDPM_CFG)
        assert np.all(np.diff(np.asarray(abars)) < 0)
        assert float(abars[0]) > 0.99 and float(abars[-1]) > 0.0

    def test_mmd_generator_shape(self):
        cfg = aot.MMDGEN_CFG._replace(hidden=16)
        params = baselines.init_gen_params(jax.random.PRNGKey(0), cfg)
        z = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.z_dim))
        img = np.asarray(baselines.generator(params, cfg, z))
        assert img.shape == (4, 16, 16, 3)
        assert img.min() >= -1.0 and img.max() <= 1.0

    def test_mmd_loss_zero_for_identical(self):
        """MMD of a distribution against itself (same samples) is ~0 after
        the diagonal terms cancel; here just check it's small vs disjoint."""
        cfg = aot.MMDGEN_CFG._replace(hidden=16)
        params = baselines.init_gen_params(jax.random.PRNGKey(0), cfg)
        real = jax.random.normal(jax.random.PRNGKey(2), (16, 16, 16, 3)) * 0.2
        l1 = float(baselines.mmd_loss(params, cfg, real, jax.random.PRNGKey(3)))
        assert np.isfinite(l1) and l1 >= -1e-3


class TestMafLowering:
    def test_maf_artifacts(self, tmp_path):
        cfg = maf.MafConfig(name="mtest", dim=8, layers=2, hidden=16,
                            dataset="ising", train_steps=1, train_batch=4, lr=1e-3)
        params = maf.init_params(jax.random.PRNGKey(0), cfg)
        w = aot.ArtifactWriter(tmp_path)
        aot.lower_maf(w, cfg, params, [4])
        w.write_manifest()
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        names = {a["name"] for a in manifest["artifacts"]}
        assert names == {"mtest_fwd_b4", "mtest_layer_jstep_b4"}
