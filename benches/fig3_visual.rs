//! **Fig 3 / A7 / A8**: visual comparison — sample sheets from sequential
//! inference and from SJD on all three datasets, plus the wall-clock ratio.

mod common;

use common::*;
use sjd::benchkit::Report;
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::sampler::Sampler;
use sjd::imageio::{compose_grid, write_png, Image};

fn main() -> anyhow::Result<()> {
    let engine = engine_or_skip();
    let mut report = Report::new("Fig 3/A7/A8 — visual comparison sequential vs SJD");
    let mut rows = Vec::new();

    for model in ["tf10", "tf100", "tfafhq"] {
        if engine.manifest().model(model).is_err() {
            continue;
        }
        let batch = *engine.manifest().model(model)?.batch_sizes.iter().max().unwrap();
        let sampler = Sampler::new(&engine, model, batch)?;
        let n = batch.min(8);

        let seq = generate(&sampler, DecodePolicy::Sequential, 0.5, n, 42)?;
        let sjd = generate(&sampler, DecodePolicy::Selective { seq_blocks: 1 }, 0.5, n, 42)?;

        let mut sheet: Vec<Image> = Vec::new();
        for t in seq.images.iter().take(8) {
            sheet.push(Image::from_tensor_pm1(t)?);
        }
        for t in sjd.images.iter().take(8) {
            sheet.push(Image::from_tensor_pm1(t)?);
        }
        let grid = compose_grid(&sheet, 8, 2);
        let p = artifacts_dir().join(format!("fig3_visual_{model}.png"));
        write_png(&grid, &p)?;
        let speed = seq.wall / sjd.wall;
        println!("{model}: sheet {} ({speed:.1}x acceleration)", p.display());
        rows.push(vec![
            paper_label(model).to_string(),
            format!("{:.1}x", speed),
            p.display().to_string(),
        ]);
    }
    report.table(&["Dataset", "Acceleration", "Sheet"], &rows);
    report.note("Same seeds per row: top = sequential, bottom = SJD — visually identical per the paper.");
    report.finish();
    Ok(())
}
