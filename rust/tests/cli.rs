//! CLI binary smoke tests (run the real `sjd` binary).

use std::process::Command;

fn artifacts() -> Option<String> {
    let dir = std::env::var("SJD_ARTIFACTS").unwrap_or_else(|_| {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts")
            .display()
            .to_string()
    });
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built");
        None
    }
}

fn run(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_sjd"))
        .args(args)
        .output()
        .expect("spawn sjd");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn help_lists_subcommands() {
    let (ok, text) = run(&["--help"]);
    assert!(!ok); // help goes through the error path with exit 2
    for cmd in ["serve", "sample", "recon", "calibrate", "policy", "info"] {
        assert!(text.contains(cmd), "missing '{cmd}' in help:\n{text}");
    }
}

#[test]
fn policy_show_prints_mode_table_without_artifacts() {
    // Parametric policy: one row per block at the requested K.
    let (ok, text) = run(&["policy", "show", "--policy", "gs:4", "--blocks", "4"]);
    assert!(ok, "{text}");
    assert!(text.contains("GS-Jacobi(W=4)"), "{text}");
    assert_eq!(text.matches("gs W=4").count(), 4, "{text}");
    // Decode position 0 maps to flow block K-1 = 3.
    assert!(text.lines().any(|l| l.starts_with('0') && l.contains('3')), "{text}");

    // Calibrated per-block policies carry their own K and mode table.
    let path = std::env::temp_dir().join("sjd_cli_policy_show.json");
    let json = r#"{"kind": "per_block", "modes": [
        {"mode": "sequential"},
        {"mode": "gs_fuse", "windows": 8, "chunk": 4},
        {"mode": "fuse", "chunk": 2}
    ]}"#;
    std::fs::write(&path, json).unwrap();
    let (ok, text) = run(&["policy", "show", "--policy-file", path.to_str().unwrap()]);
    assert!(ok, "{text}");
    assert!(text.contains("sequential"), "{text}");
    assert!(text.contains("gs_fuse W=8 S=4"), "{text}");
    assert!(text.contains("fuse S=2"), "{text}");

    // Malformed policies are rejected, not silently defaulted.
    let (ok, text) = run(&["policy", "show", "--policy", "warp:9"]);
    assert!(!ok, "{text}");
}

#[test]
fn bad_init_is_rejected_not_defaulted() {
    // A typo'd --init used to silently decode from zeros; it must be a
    // usage error on every command that takes the flag — and it must fail
    // before any artifact loading, so no artifacts are needed here.
    for cmd in [&["sample"][..], &["recon"][..], &["calibrate"][..], &["serve"][..]] {
        let mut args: Vec<&str> = cmd.to_vec();
        args.extend_from_slice(&["--init", "wurm"]);
        let (ok, text) = run(&args);
        assert!(!ok, "{cmd:?} accepted bad --init:\n{text}");
        assert!(text.contains("bad --init"), "{cmd:?}:\n{text}");
    }
    // Malformed warm caps are errors too ("warm:0" bounds nothing).
    let (ok, text) = run(&["sample", "--init", "warm:0"]);
    assert!(!ok, "{text}");
    let (ok, text) = run(&["sample", "--init", "warm:x"]);
    assert!(!ok, "{text}");
}

#[test]
fn policy_show_prints_embedded_init_section() {
    // Calibrated files may carry the init policy; `policy show` surfaces it
    // and a malformed section is an error, not a silent default.
    let path = std::env::temp_dir().join("sjd_cli_policy_init.json");
    std::fs::write(
        &path,
        r#"{"kind": "ujd", "init": {"strategy": "warm", "warm_cap": 4}}"#,
    )
    .unwrap();
    let (ok, text) = run(&["policy", "show", "--policy-file", path.to_str().unwrap()]);
    assert!(ok, "{text}");
    assert!(text.contains("init:   warm:4"), "{text}");

    let bad = std::env::temp_dir().join("sjd_cli_policy_init_bad.json");
    std::fs::write(&bad, r#"{"kind": "ujd", "init": {"strategy": "wurm"}}"#).unwrap();
    let (ok, text) = run(&["policy", "show", "--policy-file", bad.to_str().unwrap()]);
    assert!(!ok, "{text}");
}

#[test]
fn unknown_subcommand_fails() {
    let (ok, text) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown subcommand"));
}

#[test]
fn info_lists_models() {
    let Some(dir) = artifacts() else { return };
    let (ok, text) = run(&["info", "--artifacts", &dir]);
    assert!(ok, "{text}");
    assert!(text.contains("tf10"), "{text}");
    assert!(text.contains("artifacts:"));
}

#[test]
fn sample_writes_png() {
    let Some(dir) = artifacts() else { return };
    let out = std::env::temp_dir().join("sjd_cli_sample.png");
    let _ = std::fs::remove_file(&out);
    let (ok, text) = run(&[
        "sample",
        "--artifacts",
        &dir,
        "--model",
        "tf10",
        "--batch",
        "1",
        "--out",
        out.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    let bytes = std::fs::read(&out).expect("png written");
    assert_eq!(&bytes[1..4], b"PNG");
    assert!(text.contains("jacobi"));
}

#[test]
fn recon_reports_mse() {
    let Some(dir) = artifacts() else { return };
    let (ok, text) = run(&["recon", "--artifacts", &dir, "--model", "tf10", "--batch", "1"]);
    assert!(ok, "{text}");
    assert!(text.contains("reconstruction MSE"), "{text}");
}
