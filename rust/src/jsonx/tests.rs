use super::*;

#[test]
fn parse_scalars() {
    assert_eq!(parse("null").unwrap(), Value::Null);
    assert_eq!(parse("true").unwrap(), Value::Bool(true));
    assert_eq!(parse("false").unwrap(), Value::Bool(false));
    assert_eq!(parse("42").unwrap(), Value::Num(42.0));
    assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
    assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
}

#[test]
fn parse_nested() {
    let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
    assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
    let arr = v.get("a").unwrap().as_arr().unwrap();
    assert_eq!(arr.len(), 3);
    assert_eq!(arr[2].get("b").unwrap(), &Value::Null);
}

#[test]
fn parse_string_escapes() {
    let v = parse(r#""a\n\t\"\\A""#).unwrap();
    assert_eq!(v.as_str().unwrap(), "a\n\t\"\\A");
}

#[test]
fn parse_surrogate_pair() {
    let v = parse(r#""😀""#).unwrap();
    assert_eq!(v.as_str().unwrap(), "😀");
}

#[test]
fn parse_utf8_passthrough() {
    let v = parse("\"héllo ✓\"").unwrap();
    assert_eq!(v.as_str().unwrap(), "héllo ✓");
}

#[test]
fn errors_have_offsets() {
    let e = parse("{\"a\": }").unwrap_err();
    assert!(e.offset > 0);
    assert!(parse("[1,]").is_err());
    assert!(parse("1 2").is_err());
    assert!(parse("\"\\ud800\"").is_err(), "lone surrogate must fail");
}

#[test]
fn roundtrip_pretty() {
    let src = r#"{"arr": [1, 2.5, "s"], "nested": {"x": true, "y": null}, "z": -7}"#;
    let v = parse(src).unwrap();
    let emitted = to_string_pretty(&v);
    let re = parse(&emitted).unwrap();
    assert_eq!(v, re);
}

#[test]
fn deterministic_output() {
    let v = Value::obj(vec![("b", Value::num(1.0)), ("a", Value::num(2.0))]);
    let s = to_string_pretty(&v);
    // BTreeMap => sorted keys
    assert!(s.find("\"a\"").unwrap() < s.find("\"b\"").unwrap());
}

#[test]
fn accessor_helpers() {
    let v = parse(r#"{"n": 5, "s": "str", "a": [1]}"#).unwrap();
    assert_eq!(v.req_usize("n").unwrap(), 5);
    assert_eq!(v.req_str("s").unwrap(), "str");
    assert_eq!(v.req_arr("a").unwrap().len(), 1);
    assert!(v.req_str("missing").is_err());
    assert!(v.req_usize("s").is_err());
}

#[test]
fn nesting_depth_is_bounded() {
    // 128 levels parse; beyond that the parser must *error*, not recurse —
    // a stack overflow aborts the process, so depth has to be data, not
    // call stack.
    let ok = format!("{}1{}", "[".repeat(128), "]".repeat(128));
    assert!(parse(&ok).is_ok());
    let deep = format!("{}1{}", "[".repeat(20_000), "]".repeat(20_000));
    let e = parse(&deep).unwrap_err();
    assert!(e.msg.contains("nesting too deep"), "{e}");
    // Mixed containers count against the same budget.
    let mixed = "{\"k\": ".repeat(10_000) + "1" + &"}".repeat(10_000);
    assert!(parse(&mixed).is_err());
}

#[test]
fn fuzz_json_parser_never_panics_and_roundtrips() {
    // Structure-aware fuzz of the full grammar: `parse` must reject or
    // accept, never panic; any accepted document with finite numbers must
    // round-trip bit-for-bit through the emitter. (Non-finite f64s — e.g.
    // "1e999" → inf — are accepted by `parse` but have no JSON spelling,
    // so they are excluded from the round-trip leg.)
    fn finite(v: &Value) -> bool {
        match v {
            Value::Num(n) => n.is_finite(),
            Value::Arr(a) => a.iter().all(finite),
            Value::Obj(o) => o.values().all(finite),
            _ => true,
        }
    }
    let corpus: &[&[u8]] = &[
        br#"{"arr": [1, 2.5, "s"], "nested": {"x": true, "y": null}, "z": -7}"#,
        br#"{"kind": "per_block", "modes": [{"mode": "gs", "windows": 4}]}"#,
        br#"[[[{"a": "😀 A"}], -0.5e-3], "héllo", []]"#,
        br#""tab\t nl\n quote\" back\\ slash\/ done""#,
        b"12345678901234567890.000001",
        b"null",
    ];
    let dict: &[&[u8]] = &[
        b"{", b"}", b"[", b"]", b":", b",", b"\"", b"\\u", b"\\", b"null", b"true", b"false",
        b"-", b"e+", b"1e999", b"\"init\"",
    ];
    crate::testkit::fuzz::fuzz_cases(corpus, dict, 12_000, 0x15_0BAD, |case| {
        let Ok(text) = std::str::from_utf8(case) else { return };
        if let Ok(v) = parse(text) {
            if finite(&v) {
                let emitted = to_string_pretty(&v);
                let re = parse(&emitted).unwrap_or_else(|e| {
                    panic!("emitted JSON failed to reparse: {e}\n{emitted}")
                });
                assert_eq!(v, re, "round-trip changed the document");
            }
        }
    });
}

#[test]
fn big_document() {
    // Stress the parser with a generated document.
    let mut src = String::from("[");
    for i in 0..1000 {
        if i > 0 {
            src.push(',');
        }
        src.push_str(&format!("{{\"i\": {i}, \"f\": {}.5}}", i));
    }
    src.push(']');
    let v = parse(&src).unwrap();
    assert_eq!(v.as_arr().unwrap().len(), 1000);
    assert_eq!(v.as_arr().unwrap()[999].req_usize("i").unwrap(), 999);
}
