//! Elementwise ops, reductions, and distance/similarity measures on [`Tensor`].

use super::Tensor;
use anyhow::{bail, Result};

impl Tensor {
    /// Elementwise binary op; shapes must match exactly.
    pub fn zip_map(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Tensor> {
        if self.shape() != other.shape() {
            bail!("shape mismatch {:?} vs {:?}", self.shape(), other.shape());
        }
        let data = self.data().iter().zip(other.data()).map(|(&a, &b)| f(a, b)).collect();
        Tensor::new(self.shape(), data)
    }

    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        self.zip_map(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        self.zip_map(other, |a, b| a - b)
    }

    pub fn mul(&self, other: &Tensor) -> Result<Tensor> {
        self.zip_map(other, |a, b| a * b)
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor::new(self.shape(), self.data().iter().map(|&x| f(x)).collect()).unwrap()
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    pub fn sum(&self) -> f32 {
        // Kahan summation: metric code feeds large flat arrays.
        let mut sum = 0.0f64;
        for &x in self.data() {
            sum += x as f64;
        }
        sum as f32
    }

    pub fn mean(&self) -> f32 {
        if self.numel() == 0 {
            return 0.0;
        }
        self.sum() / self.numel() as f32
    }

    pub fn min(&self) -> f32 {
        self.data().iter().copied().fold(f32::INFINITY, f32::min)
    }

    pub fn max(&self) -> f32 {
        self.data().iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }

    /// L2 norm of the flattened tensor.
    pub fn l2_norm(&self) -> f32 {
        (self.data().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()).sqrt() as f32
    }

    /// L∞ norm — the Jacobi stopping criterion ‖z^t − z^{t−1}‖∞ (Alg 1).
    pub fn linf_norm(&self) -> f32 {
        self.data().iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// L2 distance to another tensor.
    pub fn l2_dist(&self, other: &Tensor) -> Result<f32> {
        Ok(self.sub(other)?.l2_norm())
    }

    /// Cosine similarity of flattened tensors (Fig 1 metric).
    pub fn cosine_sim(&self, other: &Tensor) -> Result<f32> {
        if self.shape() != other.shape() {
            bail!("shape mismatch");
        }
        let mut dot = 0.0f64;
        let mut na = 0.0f64;
        let mut nb = 0.0f64;
        for (&a, &b) in self.data().iter().zip(other.data()) {
            dot += (a as f64) * (b as f64);
            na += (a as f64) * (a as f64);
            nb += (b as f64) * (b as f64);
        }
        if na == 0.0 || nb == 0.0 {
            return Ok(0.0);
        }
        Ok((dot / (na.sqrt() * nb.sqrt())) as f32)
    }

    /// Mean squared error (reconstruction-consistency metric, §E.4).
    pub fn mse(&self, other: &Tensor) -> Result<f32> {
        if self.shape() != other.shape() {
            bail!("shape mismatch");
        }
        let n = self.numel().max(1) as f64;
        let s: f64 = self
            .data()
            .iter()
            .zip(other.data())
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        Ok((s / n) as f32)
    }

    /// Per-sample L∞ norms along axis 0 of a 2-D view (B, rest).
    pub fn linf_per_row(&self) -> Vec<f32> {
        let b = self.shape()[0];
        let inner: usize = self.shape()[1..].iter().product();
        (0..b)
            .map(|i| {
                self.data()[i * inner..(i + 1) * inner]
                    .iter()
                    .fold(0.0f32, |m, &x| m.max(x.abs()))
            })
            .collect()
    }

    /// Clamp all elements into [lo, hi].
    pub fn clamp(&self, lo: f32, hi: f32) -> Tensor {
        self.map(|x| x.clamp(lo, hi))
    }

    /// Column means of a 2-D tensor (N, D) → (D,).
    pub fn col_mean(&self) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (n, d) = (self.shape()[0], self.shape()[1]);
        let mut out = vec![0.0f64; d];
        for i in 0..n {
            for j in 0..d {
                out[j] += self.data()[i * d + j] as f64;
            }
        }
        let scale = 1.0 / n.max(1) as f64;
        Tensor::new(&[d], out.into_iter().map(|x| (x * scale) as f32).collect()).unwrap()
    }

    /// Covariance matrix of a 2-D tensor (N, D) → (D, D), unbiased.
    pub fn covariance(&self) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (n, d) = (self.shape()[0], self.shape()[1]);
        let mu = self.col_mean();
        let mut cov = vec![0.0f64; d * d];
        for i in 0..n {
            let row = self.row(i);
            for a in 0..d {
                let da = (row[a] - mu.data()[a]) as f64;
                for b in a..d {
                    let db = (row[b] - mu.data()[b]) as f64;
                    cov[a * d + b] += da * db;
                }
            }
        }
        let scale = 1.0 / (n.max(2) - 1) as f64;
        for a in 0..d {
            for b in a..d {
                let v = cov[a * d + b] * scale;
                cov[a * d + b] = v;
                cov[b * d + a] = v;
            }
        }
        Tensor::new(&[d, d], cov.into_iter().map(|x| x as f32).collect()).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], v: Vec<f32>) -> Tensor {
        Tensor::new(shape, v).unwrap()
    }

    #[test]
    fn elementwise() {
        let a = t(&[2, 2], vec![1., 2., 3., 4.]);
        let b = t(&[2, 2], vec![4., 3., 2., 1.]);
        assert_eq!(a.add(&b).unwrap().data(), &[5., 5., 5., 5.]);
        assert_eq!(a.sub(&b).unwrap().data(), &[-3., -1., 1., 3.]);
        assert_eq!(a.mul(&b).unwrap().data(), &[4., 6., 6., 4.]);
        assert!(a.add(&t(&[4], vec![0.; 4])).is_err());
    }

    #[test]
    fn norms() {
        let a = t(&[3], vec![3., -4., 0.]);
        assert!((a.l2_norm() - 5.0).abs() < 1e-6);
        assert_eq!(a.linf_norm(), 4.0);
        let b = t(&[3], vec![0., 0., 0.]);
        assert_eq!(b.linf_norm(), 0.0);
    }

    #[test]
    fn cosine() {
        let a = t(&[2], vec![1., 0.]);
        let b = t(&[2], vec![0., 1.]);
        assert!((a.cosine_sim(&b).unwrap()).abs() < 1e-6);
        assert!((a.cosine_sim(&a).unwrap() - 1.0).abs() < 1e-6);
        let z = t(&[2], vec![0., 0.]);
        assert_eq!(a.cosine_sim(&z).unwrap(), 0.0);
    }

    #[test]
    fn mse_and_dist() {
        let a = t(&[2], vec![1., 2.]);
        let b = t(&[2], vec![3., 2.]);
        assert!((a.mse(&b).unwrap() - 2.0).abs() < 1e-6);
        assert!((a.l2_dist(&b).unwrap() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn per_row_inf_norms() {
        let a = t(&[2, 3], vec![1., -5., 2., 0., 0.5, -0.25]);
        assert_eq!(a.linf_per_row(), vec![5.0, 0.5]);
    }

    #[test]
    fn stats() {
        // Two columns, perfectly correlated.
        let x = t(&[4, 2], vec![1., 2., 2., 4., 3., 6., 4., 8.]);
        let mu = x.col_mean();
        assert_eq!(mu.data(), &[2.5, 5.0]);
        let cov = x.covariance();
        // var(col0) = 5/3; cov = 10/3; var(col1) = 20/3
        assert!((cov.at(&[0, 0]) - 5.0 / 3.0).abs() < 1e-5);
        assert!((cov.at(&[0, 1]) - 10.0 / 3.0).abs() < 1e-5);
        assert!((cov.at(&[1, 1]) - 20.0 / 3.0).abs() < 1e-5);
    }

    #[test]
    fn clamp_minmax() {
        let a = t(&[4], vec![-2., 0., 0.5, 3.]);
        let c = a.clamp(0.0, 1.0);
        assert_eq!(c.data(), &[0., 0., 0.5, 1.]);
        assert_eq!(a.min(), -2.0);
        assert_eq!(a.max(), 3.0);
    }
}
