//! Batch-level quality evaluation shared by the paper-table benches:
//! proxy-FID via the `metricnet` artifact, mean BRISQUE, mean CLIP-IQA proxy.

use super::{brisque, clip_iqa_proxy, frechet_distance, FeatureStats};
use crate::imageio::Image;
use crate::runtime::{Engine, HostTensor};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};

/// Quality summary of a generated image set vs a reference set.
#[derive(Clone, Debug)]
pub struct QualityReport {
    pub fid: f32,
    pub clip_iqa: f32,
    pub brisque: f32,
    pub n_generated: usize,
    pub n_reference: usize,
}

/// Extract metricnet features for a stack of images (N, H, W, C), batching
/// to the artifact's lowered batch size.
pub fn metric_features(
    engine: &Engine,
    metric_model: &str,
    images: &Tensor,
) -> Result<Tensor> {
    if images.ndim() != 4 {
        bail!("expected (N, H, W, C) image stack, got {:?}", images.shape());
    }
    let meta = engine.manifest().model(metric_model)?;
    let batch = *meta
        .batch_sizes
        .first()
        .context("metricnet has no lowered batch size")?;
    let artifact = format!("{metric_model}_feat_b{batch}");
    let n = images.shape()[0];
    let inner: usize = images.shape()[1..].iter().product();
    let mut feats: Vec<Tensor> = Vec::new();
    let mut i = 0;
    while i < n {
        let take = batch.min(n - i);
        // Pad the last batch by repeating the first image.
        let mut data = Vec::with_capacity(batch * inner);
        data.extend_from_slice(&images.data()[i * inner..(i + take) * inner]);
        for _ in take..batch {
            data.extend_from_slice(&images.data()[i * inner..i * inner + inner]);
        }
        let mut shape = vec![batch];
        shape.extend_from_slice(&images.shape()[1..]);
        let out = engine.call(&artifact, &[HostTensor::f32(&shape, data)])?;
        let f = out.into_iter().next().context("features output")?;
        let fdim = f.shape()[1];
        let ft = Tensor::new(&[take, fdim], f.as_f32()?[..take * fdim].to_vec())?;
        feats.push(ft);
        i += take;
    }
    let refs: Vec<&Tensor> = feats.iter().collect();
    Tensor::cat0(&refs)
}

/// Full quality evaluation: FID between generated and reference stacks plus
/// the two no-reference scores on the generated set.
pub fn evaluate_quality(
    engine: &Engine,
    metric_model: &str,
    generated: &[Tensor],
    reference: &Tensor,
) -> Result<QualityReport> {
    // Stack generated images.
    let gen_refs: Vec<&Tensor> = generated.iter().collect();
    let mut gen_stack_parts = Vec::with_capacity(generated.len());
    for g in &gen_refs {
        let mut shape = vec![1];
        shape.extend_from_slice(g.shape());
        gen_stack_parts.push(g.reshape(&shape)?);
    }
    let part_refs: Vec<&Tensor> = gen_stack_parts.iter().collect();
    let gen_stack = Tensor::cat0(&part_refs)?;

    let gen_feats = metric_features(engine, metric_model, &gen_stack)?;
    let ref_feats = metric_features(engine, metric_model, reference)?;
    let fid = frechet_distance(&FeatureStats::fit(&gen_feats)?, &FeatureStats::fit(&ref_feats)?)?;

    let mut iqa_sum = 0.0f32;
    let mut brisque_sum = 0.0f32;
    for g in generated {
        let img = Image::from_tensor_pm1(g)?;
        iqa_sum += clip_iqa_proxy(&img);
        brisque_sum += brisque(&img);
    }
    let n = generated.len().max(1) as f32;
    Ok(QualityReport {
        fid,
        clip_iqa: iqa_sum / n,
        brisque: brisque_sum / n,
        n_generated: generated.len(),
        n_reference: reference.shape()[0],
    })
}
