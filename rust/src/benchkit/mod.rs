//! Micro-benchmark harness (criterion substitute): warmup, timed iterations,
//! robust statistics, and markdown table output shared by every bench binary
//! under `benches/`.
//!
//! Benches in this repo are *experiment drivers* — each regenerates one paper
//! table/figure — so the harness also provides a [`Report`] type that
//! accumulates labelled rows/series and renders them like the paper does.

use std::time::{Duration, Instant};

/// Timing statistics over repeated runs of a closure.
#[derive(Clone, Debug)]
pub struct Timing {
    pub iters: usize,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
    pub p50: Duration,
    pub stddev: Duration,
}

impl Timing {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

/// Time `f` with `warmup` unmeasured runs followed by `iters` measured runs.
pub fn time_fn(warmup: usize, iters: usize, mut f: impl FnMut()) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    summarize(&mut samples)
}

/// Summarize raw duration samples.
pub fn summarize(samples: &mut [Duration]) -> Timing {
    assert!(!samples.is_empty());
    samples.sort();
    let n = samples.len();
    let sum: Duration = samples.iter().sum();
    let mean = sum / n as u32;
    let mean_s = mean.as_secs_f64();
    let var = samples
        .iter()
        .map(|d| (d.as_secs_f64() - mean_s).powi(2))
        .sum::<f64>()
        / n as f64;
    Timing {
        iters: n,
        mean,
        min: samples[0],
        max: samples[n - 1],
        p50: samples[n / 2],
        stddev: Duration::from_secs_f64(var.sqrt()),
    }
}

/// A labelled experiment report that renders paper-style markdown tables and
/// simple ASCII series plots, and can be appended to a results file.
pub struct Report {
    title: String,
    lines: Vec<String>,
}

impl Report {
    pub fn new(title: impl Into<String>) -> Self {
        let title = title.into();
        let mut lines = Vec::new();
        lines.push(format!("\n## {title}\n"));
        Report { title, lines }
    }

    pub fn note(&mut self, s: impl AsRef<str>) {
        self.lines.push(format!("{}\n", s.as_ref()));
    }

    /// Add a markdown table.
    pub fn table(&mut self, header: &[&str], rows: &[Vec<String>]) {
        let mut line = String::from("|");
        for h in header {
            line.push_str(&format!(" {h} |"));
        }
        self.lines.push(line);
        let mut sep = String::from("|");
        for _ in header {
            sep.push_str("---|");
        }
        self.lines.push(sep);
        for row in rows {
            let mut line = String::from("|");
            for cell in row {
                line.push_str(&format!(" {cell} |"));
            }
            self.lines.push(line);
        }
        self.lines.push(String::new());
    }

    /// Add a named numeric series rendered as `label: v1 v2 v3 ...` plus an
    /// ASCII sparkline-style plot (figures in the paper become these).
    pub fn series(&mut self, label: &str, xs: &[f64]) {
        let vals: Vec<String> = xs.iter().map(|v| format!("{v:.4}")).collect();
        self.lines.push(format!("`{label}`: [{}]", vals.join(", ")));
        self.lines.push(format!("```\n{}\n```", ascii_plot(xs, 48, 8)));
    }

    /// Print to stdout and append to `EXPERIMENTS.out.md` next to the repo
    /// root (aggregated into EXPERIMENTS.md manually/at the end).
    pub fn finish(self) -> String {
        let body = self.lines.join("\n");
        println!("{body}");
        let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("EXPERIMENTS.out.md");
        if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(out) {
            use std::io::Write as _;
            let _ = writeln!(f, "{body}");
        }
        log::info!("report '{}' finished", self.title);
        body
    }
}

/// Tiny ASCII line plot for figure-style series.
pub fn ascii_plot(xs: &[f64], width: usize, height: usize) -> String {
    if xs.is_empty() {
        return String::from("(empty)");
    }
    let lo = xs.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    let w = width.min(xs.len().max(1));
    let mut grid = vec![vec![b' '; w]; height];
    for col in 0..w {
        let idx = col * (xs.len() - 1).max(1) / (w - 1).max(1);
        let v = xs[idx.min(xs.len() - 1)];
        let r = ((v - lo) / span * (height - 1) as f64).round() as usize;
        let row = height - 1 - r.min(height - 1);
        grid[row][col] = b'*';
    }
    let mut out = String::new();
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            format!("{hi:>10.3} |")
        } else if i == height - 1 {
            format!("{lo:>10.3} |")
        } else {
            format!("{:>10} |", "")
        };
        out.push_str(&label);
        out.push_str(std::str::from_utf8(row).unwrap());
        out.push('\n');
    }
    out
}

/// Format a duration like the paper's tables (seconds with 2 decimals).
pub fn fmt_secs(d: Duration) -> String {
    format!("{:.2}", d.as_secs_f64())
}

/// Format a speedup factor like the paper (e.g. "3.6x").
pub fn fmt_speedup(base: Duration, ours: Duration) -> String {
    format!("{:.1}x", base.as_secs_f64() / ours.as_secs_f64().max(1e-12))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_monotone_stats() {
        let t = time_fn(1, 20, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(t.iters, 20);
        assert!(t.min <= t.p50 && t.p50 <= t.max);
        assert!(t.mean >= t.min && t.mean <= t.max);
    }

    #[test]
    fn summarize_known_values() {
        let mut s = vec![
            Duration::from_millis(10),
            Duration::from_millis(20),
            Duration::from_millis(30),
        ];
        let t = summarize(&mut s);
        assert_eq!(t.mean, Duration::from_millis(20));
        assert_eq!(t.min, Duration::from_millis(10));
        assert_eq!(t.max, Duration::from_millis(30));
    }

    #[test]
    fn report_table_render() {
        let mut r = Report::new("test-table");
        r.table(
            &["Method", "Time"],
            &[vec!["Seq".into(), "9.5".into()], vec!["Ours".into(), "2.6".into()]],
        );
        let body = r.finish();
        assert!(body.contains("| Method | Time |"));
        assert!(body.contains("| Ours | 2.6 |"));
    }

    #[test]
    fn plot_handles_flat_and_empty() {
        assert_eq!(ascii_plot(&[], 10, 4), "(empty)");
        let p = ascii_plot(&[1.0, 1.0, 1.0], 10, 4);
        assert!(p.contains('*'));
    }

    #[test]
    fn speedup_format() {
        assert_eq!(
            fmt_speedup(Duration::from_secs(9), Duration::from_secs(3)),
            "3.0x"
        );
    }
}
