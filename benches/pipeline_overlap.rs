//! **Stage-graph pipelining**: throughput and latency of 2-deep inter-batch
//! block overlap vs single-in-flight decode, over the **mock backend** — no
//! artifacts needed, so it runs everywhere (including the CI smoke step).
//!
//! Both configurations drive the same `DecodePipeline` (one stage thread
//! per flow block, each charging batch-proportional kernel time per jstep
//! call); only the depth gate differs. At depth 1 a batch must clear all K
//! stages before the next enters — the monolithic worker's schedule. At
//! depth 2, batch B occupies stage 0 while batch A is in stage 1, so with
//! roughly balanced stages steady-state throughput approaches 2×.
//!
//! The acceptance gate mirrors the equivalence test in
//! `rust/tests/mock_backend.rs`: at τ = 0 both depths must produce
//! **bit-identical tokens**, the 2-deep run must beat single-in-flight on
//! throughput by ≥ 1.3×, and per-batch decode latency (p99) must stay
//! within 1.5× — overlap must come from the stage graph, not from queueing
//! batches deeper. Exits non-zero otherwise.
//!
//! ```bash
//! cargo bench --bench pipeline_overlap            # full run (24 batches)
//! cargo bench --bench pipeline_overlap -- --quick # CI smoke (12 batches)
//! ```

use anyhow::Result;
use sjd::benchkit::Report;
use sjd::coordinator::pipeline::{DecodePipeline, PipelineConfig, PipelineJob};
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::sampler::SampleOptions;
use sjd::metrics::Registry;
use sjd::runtime::HostTensor;
use sjd::testkit::mockflow::{MockLedger, MockServeBackend};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Per-step kernel time (× batch size per jstep call) — makes stage
/// occupancy real wall time the overlap can reclaim.
const SLOT_DELAY: Duration = Duration::from_micros(500);

fn quick() -> bool {
    std::env::args().any(|a| a == "--quick") || std::env::var("SJD_QUICK").is_ok()
}

struct RunStats {
    wall: Duration,
    /// Per-batch decode latency (stage-0 start → completion), ms, sorted.
    latencies_ms: Vec<f64>,
    tokens: BTreeMap<u64, HostTensor>,
    stage_waits: u64,
}

fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() as f64 - 1.0) * q) as usize]
}

fn run(depth: usize, n_batches: u64) -> Result<RunStats> {
    let registry = Registry::new();
    let cfg = PipelineConfig { depth, stage_threads: 0, warm_cap: 0, ..Default::default() };
    let factory =
        move |_stage: usize| Ok(MockServeBackend::new(&[2], SLOT_DELAY, MockLedger::new()));
    let pipeline = DecodePipeline::start("mock", &[2], cfg, registry.clone(), factory)?;

    // τ = 0: every block runs its full L-iteration exactness sweep, so the
    // stages are balanced AND the outputs are bit-comparable across depths.
    let mut opts = SampleOptions { policy: DecodePolicy::UniformJacobi, ..Default::default() };
    opts.jacobi.tau = 0.0;

    let results: Arc<Mutex<BTreeMap<u64, (HostTensor, f64)>>> =
        Arc::new(Mutex::new(BTreeMap::new()));
    let t0 = Instant::now();
    for seed in 0..n_batches {
        let results = results.clone();
        let job = PipelineJob {
            seeds: vec![seed, seed.wrapping_add(100)],
            opts: opts.clone(),
            done: Box::new(move |res| {
                let (_imgs, out) = res.expect("pipeline decode");
                let lat_ms = out.total_wall.as_secs_f64() * 1e3;
                results.lock().unwrap().insert(seed, (out.tokens, lat_ms));
            }),
        };
        if pipeline.submit(job).is_err() {
            anyhow::bail!("pipeline rejected a submission");
        }
    }
    pipeline.shutdown(); // drains the in-flight tail
    let wall = t0.elapsed();

    let results = Arc::try_unwrap(results).ok().expect("all callbacks done").into_inner().unwrap();
    anyhow::ensure!(results.len() == n_batches as usize, "every batch must complete");
    let mut latencies_ms: Vec<f64> = results.values().map(|(_, l)| *l).collect();
    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let tokens = results.into_iter().map(|(s, (t, _))| (s, t)).collect();
    Ok(RunStats {
        wall,
        latencies_ms,
        tokens,
        stage_waits: registry.histogram("sjd_stage_wait").count(),
    })
}

fn main() -> Result<()> {
    let n_batches: u64 = if quick() { 12 } else { 24 };
    println!(
        "=== pipeline_overlap: {n_batches} batches, depth 1 vs depth 2 \
         (mock backend, 4 stage threads) ==="
    );
    let mut report = Report::new("Stage-graph pipelining — 2-deep block overlap vs monolithic");

    let mono = run(1, n_batches)?;
    let piped = run(2, n_batches)?;

    let thr = |s: &RunStats| n_batches as f64 / s.wall.as_secs_f64();
    let rows: Vec<Vec<String>> = [("depth 1", &mono), ("depth 2", &piped)]
        .iter()
        .map(|&(label, s)| {
            vec![
                label.to_string(),
                format!("{:.2}", s.wall.as_secs_f64()),
                format!("{:.1}", thr(s)),
                format!("{:.1}", pct(&s.latencies_ms, 0.5)),
                format!("{:.1}", pct(&s.latencies_ms, 0.99)),
                s.stage_waits.to_string(),
            ]
        })
        .collect();
    for r in &rows {
        println!(
            "{:>8}: {}s wall, {} batches/s, batch ms p50 {} p99 {}, {} stage-queue passes",
            r[0], r[1], r[2], r[3], r[4], r[5]
        );
    }
    report.table(
        &["config", "wall (s)", "batches/s", "batch p50 (ms)", "batch p99 (ms)", "stage passes"],
        &rows,
    );

    let equal_output = mono.tokens == piped.tokens;
    let thr_gain = thr(&piped) / thr(&mono);
    let p99_ratio = pct(&piped.latencies_ms, 0.99) / pct(&mono.latencies_ms, 0.99).max(1e-9);
    let pass = equal_output && thr_gain >= 1.3 && p99_ratio <= 1.5;
    report.note(if pass {
        "PASS: 2 batches in flight beat single-in-flight on throughput (≥1.3×) \
         with bit-identical τ=0 output at comparable per-batch latency."
    } else {
        "FAIL: block pipelining must raise throughput at equal output without \
         inflating per-batch latency."
    });
    report.note(format!(
        "throughput ×{thr_gain:.2} (gate ≥1.3), batch p99 ratio {p99_ratio:.2} (gate ≤1.5), \
         equal output: {equal_output}"
    ));
    report.finish();
    anyhow::ensure!(equal_output, "depth-2 τ=0 output diverged from depth-1");
    anyhow::ensure!(thr_gain >= 1.3, "block pipelining gained only {thr_gain:.2}x throughput");
    anyhow::ensure!(p99_ratio <= 1.5, "depth-2 p99 inflated {p99_ratio:.2}x");
    Ok(())
}
