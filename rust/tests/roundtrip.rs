//! Integration test for the python-AOT → rust-runtime round trip.
//!
//! Uses `artifacts/smoke.hlo.txt` — a Pallas (interpret=True) kernel
//! `f(x, y) = x @ y + 2` lowered by the same path `aot.py` uses for the real
//! model artifacts. Skipped (with a loud message) if artifacts are missing;
//! `make artifacts` builds them.

use sjd::runtime::{Engine, HostTensor, Manifest, Value};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Build an engine over the smoke artifact, or `None` when artifacts are
/// missing (skip with a loud message).
fn smoke_engine(tmp_name: &str) -> Option<Engine> {
    let dir = artifacts_dir();
    let smoke = dir.join("smoke.hlo.txt");
    if !smoke.exists() {
        eprintln!("SKIP: {} missing — run `make artifacts`", smoke.display());
        return None;
    }
    // Build a manifest in-memory via a temp file so the engine path is the
    // same one production uses.
    let tmp = std::env::temp_dir().join(tmp_name);
    std::fs::create_dir_all(&tmp).unwrap();
    std::fs::copy(&smoke, tmp.join("smoke.hlo.txt")).unwrap();
    std::fs::write(
        tmp.join("manifest.json"),
        r#"{
          "artifacts": [
            {"name": "smoke", "file": "smoke.hlo.txt",
             "inputs": [
               {"name": "x", "dtype": "f32", "shape": [2, 2]},
               {"name": "y", "dtype": "f32", "shape": [2, 2]}
             ],
             "outputs": [
               {"name": "out", "dtype": "f32", "shape": [2, 2]}
             ]}
          ],
          "models": []
        }"#,
    )
    .unwrap();

    let manifest = Manifest::load(tmp.join("manifest.json")).unwrap();
    Some(Engine::with_manifest(manifest).unwrap())
}

#[test]
fn smoke_pallas_kernel_roundtrip() {
    let Some(engine) = smoke_engine("sjd_smoke_manifest") else { return };
    assert!(engine.platform().to_lowercase().contains("cpu") || !engine.platform().is_empty());

    let x = HostTensor::f32(&[2, 2], vec![1., 2., 3., 4.]);
    let y = HostTensor::f32(&[2, 2], vec![1., 1., 1., 1.]);
    let out = engine.call("smoke", &[x, y]).unwrap();
    assert_eq!(out.len(), 1);
    // matmul([[1,2],[3,4]], ones) + 2 = [[5,5],[9,9]]
    assert_eq!(out[0].as_f32().unwrap(), &[5., 5., 9., 9.]);

    // Stats recorded.
    let stats = engine.stats();
    assert_eq!(stats["smoke"].calls, 1);
    assert!(stats["smoke"].compile_time.as_nanos() > 0);

    // Shape validation fires.
    let bad = HostTensor::f32(&[2, 3], vec![0.; 6]);
    let y2 = HostTensor::f32(&[2, 2], vec![1.; 4]);
    assert!(engine.call("smoke", &[bad, y2]).is_err());
}

#[test]
fn value_api_accounts_marshals_and_chains() {
    let Some(engine) = smoke_engine("sjd_smoke_manifest_v") else { return };

    let x = HostTensor::f32(&[2, 2], vec![1., 2., 3., 4.]);
    let y = HostTensor::f32(&[2, 2], vec![1., 1., 1., 1.]);

    // First call: both inputs arrive host-side → 2 promotions, and the
    // promotion cost must land in marshal_time (the stat the old
    // `call_buffers` fast path silently dropped).
    let out = engine
        .call_v("smoke", &[Value::Host(x), Value::Host(y.clone())])
        .unwrap();
    assert_eq!(out.len(), 1);
    {
        let stats = engine.stats();
        let s = &stats["smoke"];
        assert_eq!(s.calls, 1);
        assert_eq!(s.host_marshals, 2);
        assert_eq!(s.device_hits, 0);
        assert!(
            s.marshal_time.as_nanos() > 0,
            "host-arg promotion must be charged to marshal_time"
        );
    }
    // "smoke" is a legacy tuple-rooted single-output artifact, so its output
    // takes the documented forced-sync fallback and arrives host-resident
    // with the correct payload — correctness never depends on whether the
    // runtime untupled the root.
    let out0 = out.into_iter().next().unwrap();
    let t0 = engine.to_host(out0.clone()).unwrap();
    assert_eq!(t0.as_f32().unwrap(), &[5., 5., 9., 9.]);

    // Chain the output into a second call next to one pinned upload: the
    // device input counts as a device hit, the host one as a promotion.
    let y_dev = engine.to_device(&y).unwrap();
    assert!(y_dev.is_device());
    let out2 = engine.call_v("smoke", &[out0, y_dev.clone()]).unwrap();
    {
        let stats = engine.stats();
        let s = &stats["smoke"];
        assert_eq!(s.calls, 2);
        assert_eq!(s.device_hits + s.host_marshals, 4, "2 inputs per call");
        assert!(s.device_hits >= 1, "the pinned upload must count as a device hit");
    }

    // smoke(smoke(x, y), y) = (x@1 + 2)@1 + 2 = [[12,12],[20,20]].
    let t = engine.to_host(out2.into_iter().next().unwrap()).unwrap();
    assert_eq!(t.as_f32().unwrap(), &[12., 12., 20., 20.]);

    // An all-device-input call must add no marshal time (promotion is the
    // only input-side marshal source).
    let before = engine.stats()["smoke"].marshal_time;
    let calls_before = engine.stats()["smoke"].calls;
    let out3 = engine.call_v("smoke", &[y_dev.clone(), y_dev]).unwrap();
    let stats = engine.stats();
    let s = &stats["smoke"];
    assert_eq!(s.calls, calls_before + 1);
    // Running totals: call1 = 2 host, call2 = 1 host + 1 device, call3 = 2 device.
    assert_eq!(s.device_hits, 3);
    assert_eq!(s.host_marshals, 3);
    // Output-side destructure of this legacy artifact may add marshal time;
    // input-side must not. Bound it: the delta is exactly the output
    // fallback of one call, which also ran in call #1 — so per-call marshal
    // cannot grow from input handling. (Exact equality would be flaky.)
    assert!(s.marshal_time >= before);
    let _ = engine.to_host(out3.into_iter().next().unwrap()).unwrap();

    // Explicit transfers recorded engine-wide (uploads: y_dev only; syncs:
    // only device values fetched via to_host — host-fallback outputs are
    // free to fetch).
    let xfer = engine.transfer_stats();
    assert_eq!(xfer.uploads, 1);

    // reset_stats clears the value-path counters too.
    engine.reset_stats();
    let stats = engine.stats();
    let s = &stats["smoke"];
    assert_eq!((s.calls, s.device_hits, s.host_marshals), (0, 0, 0));
    assert_eq!(engine.transfer_stats().uploads, 0);
}
