//! **Table A3**: average number of Jacobi iterations per layer under SJD
//! (τ = 0.5). Layer 1 is sequential (L−1 steps); the Jacobi layers converge
//! in a handful of iterations, far below the worst-case L.

mod common;

use common::*;
use sjd::benchkit::Report;
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::sampler::Sampler;

fn main() -> anyhow::Result<()> {
    let engine = engine_or_skip();
    let mut report = Report::new("Table A3 — average Jacobi iterations per layer (τ = 0.5)");
    let mut rows: Vec<Vec<String>> = Vec::new();
    let models: Vec<&str> = ["tf10", "tf100", "tfafhq"]
        .into_iter()
        .filter(|m| engine.manifest().model(m).is_ok())
        .collect();
    let mut per_model: Vec<Vec<String>> = Vec::new();
    let mut max_k = 0;

    for model in &models {
        let batch = *engine.manifest().model(model)?.batch_sizes.iter().max().unwrap();
        let sampler = Sampler::new(&engine, model, batch)?;
        let n = if quick() { batch } else { batch * 4 };
        let _ = generate(&sampler, DecodePolicy::Selective { seq_blocks: 1 }, 0.5, batch, 1)?;
        let run = generate(&sampler, DecodePolicy::Selective { seq_blocks: 1 }, 0.5, n, 42)?;
        let kk = sampler.meta.blocks;
        max_k = max_k.max(kk);
        let col: Vec<String> = (0..kk)
            .map(|pos| {
                let m = mean_usize(&run.per_position_steps[pos]);
                if pos == 0 {
                    format!("{m:.0} (seq)")
                } else {
                    format!("{m:.1}")
                }
            })
            .collect();
        println!("{model}: {col:?}");
        per_model.push(col);
    }

    for pos in 0..max_k {
        let mut row = vec![if pos == 0 {
            "1 (Sequential)".to_string()
        } else {
            format!("{} (Jacobi)", pos + 1)
        }];
        for col in &per_model {
            row.push(col.get(pos).cloned().unwrap_or_else(|| "—".into()));
        }
        rows.push(row);
    }
    let mut header = vec!["Layer"];
    header.extend(models.iter().map(|m| paper_label(m)));
    report.table(&header, &rows);
    report.note("Paper shape: Jacobi layers need ~4-8 iterations ≪ L; layer 2 needs the most (depthwise heterogeneity).");
    report.finish();
    Ok(())
}
