//! Lightweight dense tensor substrate (ndarray substitute).
//!
//! The coordinator needs host-side math — prior sampling, norms, per-token
//! slicing, image (un)patchify, metric statistics — without any crates.io
//! dependency. `Tensor` is a contiguous row-major `f32` array with shape.

mod linalg;
mod ops;
mod rng;
mod shape;

pub use linalg::{cholesky, matmul, sym_eigen, trace};
pub use rng::Pcg64;
pub use shape::strides_for;

use anyhow::{bail, Result};

/// Contiguous row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} needs {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    /// Standard-normal tensor (Box–Muller over PCG64).
    pub fn randn(shape: &[usize], rng: &mut Pcg64) -> Self {
        let n: usize = shape.iter().product();
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(rng.next_gaussian());
        }
        Tensor { shape: shape.to_vec(), data }
    }

    /// Uniform [0,1) tensor.
    pub fn rand(shape: &[usize], rng: &mut Pcg64) -> Self {
        let n: usize = shape.iter().product();
        let mut data = Vec::with_capacity(n);
        for _ in 0..n {
            data.push(rng.next_f32());
        }
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of the same element count.
    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {:?} ({} elems) to {:?}", self.shape, self.data.len(), shape);
        }
        Ok(Tensor { shape: shape.to_vec(), data: self.data.clone() })
    }

    /// Row `i` of a 2-D tensor as a slice.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.ndim(), 2, "row() requires 2-D tensor");
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    /// Index into an arbitrary-rank tensor.
    pub fn at(&self, idx: &[usize]) -> f32 {
        debug_assert_eq!(idx.len(), self.shape.len());
        let strides = strides_for(&self.shape);
        let off: usize = idx.iter().zip(&strides).map(|(i, s)| i * s).sum();
        self.data[off]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let strides = strides_for(&self.shape);
        let off: usize = idx.iter().zip(&strides).map(|(i, s)| i * s).sum();
        self.data[off] = v;
    }

    /// Slice the leading axis: rows `[start, end)` of axis 0.
    pub fn slice0(&self, start: usize, end: usize) -> Tensor {
        assert!(end <= self.shape[0] && start <= end);
        let inner: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = end - start;
        Tensor { shape, data: self.data[start * inner..end * inner].to_vec() }
    }

    /// Concatenate along axis 0.
    pub fn cat0(parts: &[&Tensor]) -> Result<Tensor> {
        if parts.is_empty() {
            bail!("cat0 of zero tensors");
        }
        let inner_shape = &parts[0].shape[1..];
        let mut total = 0;
        for p in parts {
            if &p.shape[1..] != inner_shape {
                bail!("cat0 inner shape mismatch");
            }
            total += p.shape[0];
        }
        let mut shape = parts[0].shape.clone();
        shape[0] = total;
        let mut data = Vec::with_capacity(shape.iter().product());
        for p in parts {
            data.extend_from_slice(&p.data);
        }
        Ok(Tensor { shape, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let t = Tensor::new(&[2, 3], vec![0., 1., 2., 3., 4., 5.]).unwrap();
        assert_eq!(t.at(&[0, 2]), 2.0);
        assert_eq!(t.at(&[1, 0]), 3.0);
        assert_eq!(t.row(1), &[3., 4., 5.]);
        assert!(Tensor::new(&[2, 2], vec![0.; 3]).is_err());
    }

    #[test]
    fn reshape_checks_count() {
        let t = Tensor::zeros(&[4, 2]);
        assert!(t.reshape(&[2, 4]).is_ok());
        assert!(t.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn slice_and_cat_roundtrip() {
        let t = Tensor::new(&[4, 2], (0..8).map(|x| x as f32).collect()).unwrap();
        let a = t.slice0(0, 2);
        let b = t.slice0(2, 4);
        let back = Tensor::cat0(&[&a, &b]).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn randn_moments() {
        let mut rng = Pcg64::seed(42);
        let t = Tensor::randn(&[10_000], &mut rng);
        let mean = t.data().iter().sum::<f32>() / 10_000.0;
        let var = t.data().iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / 10_000.0;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn set_get() {
        let mut t = Tensor::zeros(&[2, 2, 2]);
        t.set(&[1, 0, 1], 7.0);
        assert_eq!(t.at(&[1, 0, 1]), 7.0);
        assert_eq!(t.data().iter().filter(|&&x| x != 0.0).count(), 1);
    }
}
