//! Quickstart: load the engine, sample a batch with Selective Jacobi
//! Decoding, compare against the sequential baseline, write a PNG grid.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::sampler::{SampleOptions, Sampler};
use sjd::imageio::{compose_grid, write_png, Image};
use sjd::runtime::Engine;
use sjd::tensor::Pcg64;

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let engine = Engine::new(&artifacts)?;
    println!("PJRT platform: {}", engine.platform());

    let sampler = Sampler::new(&engine, "tf10", 8)?;
    println!(
        "model tf10: K={} blocks, L={} tokens, D={} dims",
        sampler.meta.blocks, sampler.meta.seq_len, sampler.meta.token_dim
    );

    // Warm up: compile both decode paths before timing.
    let mut rng = Pcg64::seed(1);
    let _ = sampler.sample_images(
        &SampleOptions { policy: DecodePolicy::Sequential, ..Default::default() },
        &mut rng,
    )?;
    let _ = sampler.sample_images(&SampleOptions::default(), &mut rng)?;

    // Sequential baseline.
    let mut rng = Pcg64::seed(42);
    let seq_opts = SampleOptions { policy: DecodePolicy::Sequential, ..Default::default() };
    let (seq_imgs, seq_out) = sampler.sample_images(&seq_opts, &mut rng)?;
    println!("sequential: {:.3}s", seq_out.total_wall.as_secs_f64());

    // Selective Jacobi Decoding (paper default: τ = 0.5, first block seq).
    let mut rng = Pcg64::seed(42);
    let sjd_opts = SampleOptions::default();
    let (sjd_imgs, sjd_out) = sampler.sample_images(&sjd_opts, &mut rng)?;
    println!(
        "SJD:        {:.3}s → {:.1}x speedup",
        sjd_out.total_wall.as_secs_f64(),
        seq_out.total_wall.as_secs_f64() / sjd_out.total_wall.as_secs_f64()
    );
    for t in &sjd_out.traces {
        println!(
            "  pos {} block {}: {} {} steps, {:.1} ms",
            t.position,
            t.block,
            if t.used_jacobi { "jacobi" } else { "seq" },
            t.steps,
            t.wall.as_secs_f64() * 1e3
        );
    }

    // Same seed ⇒ visually identical outputs (τ-bounded deviation).
    let mut all: Vec<Image> = Vec::new();
    for img in seq_imgs.iter().chain(sjd_imgs.iter()) {
        all.push(Image::from_tensor_pm1(img)?);
    }
    let grid = compose_grid(&all, 8, 2);
    write_png(&grid, "quickstart.png")?;
    println!("wrote quickstart.png (row 1: sequential, row 2: SJD)");
    Ok(())
}
