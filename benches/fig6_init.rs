//! **Fig 6**: initialization ablation — the full `--init` provider sweep
//! (zeros, N(0, I), previous-layer, projection, draft-then-refine,
//! warm-start) as the Jacobi starting point, on real artifacts. Paper
//! shape: acceleration is insensitive to the *statistical* initializations
//! (superlinear local convergence dominates); the speculative providers
//! are judged on `total_updates_with_spec()` — refine updates plus the
//! speculation's own cost — which is what the serving tuner gates on.
//!
//! Every rep decodes the same seed so the warm-start row sees the
//! repeat-traffic regime it exists for (its first rep is the cold fill).

mod common;

use common::*;
use sjd::benchkit::Report;
use sjd::coordinator::jacobi::InitStrategy;
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::sampler::{SampleOptions, Sampler};
use sjd::tensor::Pcg64;

fn main() -> anyhow::Result<()> {
    let engine = engine_or_skip();
    let model = "tf10";
    let batch = *engine.manifest().model(model)?.batch_sizes.iter().max().unwrap();
    let sampler = Sampler::new(&engine, model, batch)?;
    let reps = if quick() { 2 } else { 8 };

    let mut report = Report::new("Fig 6 — initialization ablation");
    let mut rows = Vec::new();

    for (init, label) in [
        (InitStrategy::Zeros, "zeros"),
        (InitStrategy::Normal, "N(0, I)"),
        (InitStrategy::PrevLayer, "prev layer"),
        (InitStrategy::Proj, "projection"),
        (InitStrategy::Draft, "draft-refine"),
        (InitStrategy::Warm, "warm-start"),
    ] {
        let mut opts = SampleOptions {
            policy: DecodePolicy::Selective { seq_blocks: 1 },
            ..Default::default()
        };
        opts.jacobi.init = init;
        // Warmup (for the warm-start row this is also the cache fill —
        // opts.seed stays fixed so every timed rep replays the same keys).
        let mut rng = Pcg64::seed(1);
        let _ = sampler.sample_images(&opts, &mut rng)?;
        let mut wall = 0.0;
        let mut iters = 0usize;
        let mut updates = 0usize;
        let mut hits = 0usize;
        for _ in 0..reps {
            // Identical request every rep — the repeat-traffic regime —
            // so the warm row's cached iterates are genuine fixed points.
            let mut rng = Pcg64::seed(100);
            let (_, out) = sampler.sample_images(&opts, &mut rng)?;
            wall += out.total_wall.as_secs_f64();
            iters += out.total_jacobi_iters();
            updates += out.total_updates_with_spec();
            hits += out.spec_hits();
        }
        let per_batch = wall / reps as f64;
        let mean_iters = iters as f64 / reps as f64;
        let mean_updates = updates as f64 / reps as f64;
        println!(
            "{label}: {per_batch:.3}s/batch, {mean_iters:.1} jacobi iters, \
             {mean_updates:.0} updates (+spec), {hits} spec hits"
        );
        rows.push(vec![
            label.into(),
            format!("{per_batch:.3}"),
            format!("{mean_iters:.1}"),
            format!("{mean_updates:.0}"),
            hits.to_string(),
        ]);
    }

    report.table(
        &["Initialization", "Time/batch (s)", "Mean Jacobi iters", "Updates (+spec)", "Spec hits"],
        &rows,
    );
    report.note(
        "Paper shape: the statistical initializations give similar acceleration; \
         the speculative providers only pay when their updates (+spec) column \
         beats zeros — the serving tuner measures exactly this and falls back \
         otherwise (benches/spec_init.rs gates it on the mock).",
    );
    report.finish();
    Ok(())
}
