//! Minimal HTTP/1.1 server front end.
//!
//! Routes:
//! * `POST /generate` — body `{"n": 4, "seed": 7}` → JSON with base64 PNGs.
//! * `GET /metrics`   — text exposition of the metrics registry.
//! * `GET /healthz`   — liveness.
//!
//! The HTTP layer is deliberately small (request line + headers +
//! content-length bodies, one request per connection unless keep-alive) —
//! it exists so the serving loop is exercised end-to-end, not to be a
//! general web server. It is still defensive where it must be: header
//! size/count are capped so a client streaming headers can't grow memory
//! unboundedly, error bodies go through the `jsonx` emitter so they stay
//! valid JSON whatever the message contains, and malformed requests (400)
//! are distinguished from internal failures (500).

use super::batcher::Batcher;
use crate::imageio::{self, Image};
use crate::jsonx::{self, Value};
use crate::metrics::Registry;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Total bytes allowed for the request line + all headers.
const MAX_HEADER_BYTES: usize = 64 << 10;
/// Maximum number of header lines.
const MAX_HEADERS: usize = 128;
/// Maximum request body size.
const MAX_BODY_BYTES: usize = 64 << 20;

/// A parsed HTTP request.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

/// Read one `\n`-terminated line without buffering more than `max` bytes.
///
/// Returns an empty string at a clean EOF (no bytes read), mirroring
/// `read_line`'s 0-return so callers can treat it as end-of-headers.
fn read_line_capped(reader: &mut impl BufRead, max: usize) -> Result<String> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let (done, used) = {
            let available = reader.fill_buf()?;
            if available.is_empty() {
                (true, 0)
            } else {
                match available.iter().position(|&b| b == b'\n') {
                    Some(i) => {
                        buf.extend_from_slice(&available[..=i]);
                        (true, i + 1)
                    }
                    None => {
                        buf.extend_from_slice(available);
                        (false, available.len())
                    }
                }
            }
        };
        reader.consume(used);
        if buf.len() > max {
            bail!("header line exceeds {max} bytes");
        }
        if done {
            break;
        }
    }
    String::from_utf8(buf).context("header not utf-8")
}

/// Parse one HTTP/1.1 request from a buffered stream.
///
/// Header bytes (request line included) are capped at [`MAX_HEADER_BYTES`]
/// and header count at [`MAX_HEADERS`] — a client streaming an endless
/// header section gets an error instead of unbounded buffering.
pub fn parse_request(reader: &mut impl BufRead) -> Result<HttpRequest> {
    let mut budget = MAX_HEADER_BYTES;
    let line = read_line_capped(reader, budget)?;
    if line.is_empty() {
        bail!("connection closed");
    }
    budget = budget.saturating_sub(line.len());
    let mut parts = line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let path = parts.next().context("missing path")?.to_string();
    let version = parts.next().context("missing version")?;
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported version {version}");
    }

    let mut content_length = 0usize;
    let mut n_headers = 0usize;
    loop {
        if budget == 0 {
            bail!("headers exceed {MAX_HEADER_BYTES} bytes");
        }
        let h = read_line_capped(reader, budget)?;
        budget = budget.saturating_sub(h.len());
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        n_headers += 1;
        if n_headers > MAX_HEADERS {
            bail!("too many headers (> {MAX_HEADERS})");
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().context("bad content-length")?;
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        bail!("body too large");
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(HttpRequest { method, path, body })
}

/// Serialize an HTTP response.
pub fn write_response(
    stream: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &[u8],
) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        500 => "Internal Server Error",
        _ => "",
    };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    stream.write_all(body)?;
    Ok(())
}

/// JSON error body built through the `jsonx` emitter, so messages containing
/// quotes/backslashes stay valid JSON (a `format!` template would not).
pub fn error_json(err: &anyhow::Error) -> String {
    jsonx::to_string_pretty(&Value::obj(vec![("error", Value::str(format!("{err:#}")))]))
}

/// Standard base64 (RFC 4648) encoding for PNG payloads in JSON responses.
pub fn base64_encode(data: &[u8]) -> String {
    const TABLE: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";
    let mut out = String::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b = [chunk[0], *chunk.get(1).unwrap_or(&0), *chunk.get(2).unwrap_or(&0)];
        let n = ((b[0] as u32) << 16) | ((b[1] as u32) << 8) | b[2] as u32;
        out.push(TABLE[(n >> 18) as usize & 63] as char);
        out.push(TABLE[(n >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 { TABLE[(n >> 6) as usize & 63] as char } else { '=' });
        out.push(if chunk.len() > 2 { TABLE[n as usize & 63] as char } else { '=' });
    }
    out
}

/// Parse and validate a `/generate` body → `(n, seed)`. Failures here are
/// the client's fault (HTTP 400); failures past this point are ours (500).
fn parse_generate_body(body: &[u8]) -> Result<(usize, u64)> {
    let text = std::str::from_utf8(body).context("body not utf-8")?;
    let v = if text.trim().is_empty() {
        Value::obj(vec![])
    } else {
        jsonx::parse(text).context("bad json")?
    };
    let n = v.get("n").and_then(Value::as_usize).unwrap_or(1).clamp(1, 64);
    let seed = v.get("seed").and_then(Value::as_usize).unwrap_or(0) as u64;
    Ok((n, seed))
}

/// Serving front end bound to a batcher + metrics registry.
pub struct Server {
    pub addr: String,
    batcher: Batcher,
    registry: Registry,
    next_request_id: AtomicU64,
    stop: Arc<AtomicBool>,
}

impl Server {
    pub fn new(addr: impl Into<String>, batcher: Batcher, registry: Registry) -> Self {
        Server {
            addr: addr.into(),
            batcher,
            registry,
            next_request_id: AtomicU64::new(1),
            stop: Arc::new(AtomicBool::new(false)),
        }
    }

    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    /// Blocking accept loop; returns when the stop flag is set (checked
    /// between connections — pair with a dummy connection to unblock).
    pub fn run(&self) -> Result<()> {
        let listener = TcpListener::bind(&self.addr)
            .with_context(|| format!("binding {}", self.addr))?;
        log::info!("listening on {}", self.addr);
        for conn in listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    if let Err(e) = self.handle(stream) {
                        log::warn!("connection error: {e:#}");
                    }
                }
                Err(e) => log::warn!("accept error: {e}"),
            }
        }
        Ok(())
    }

    fn handle(&self, stream: TcpStream) -> Result<()> {
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut stream = stream;
        let req = match parse_request(&mut reader) {
            Ok(r) => r,
            Err(e) => {
                // Malformed or oversized request framing is the client's
                // fault: answer 400 (best effort — the peer may already be
                // gone) instead of silently resetting the connection.
                self.registry.counter("sjd_http_errors").inc();
                let _ =
                    write_response(&mut stream, 400, "application/json", error_json(&e).as_bytes());
                return Err(e);
            }
        };
        self.registry.counter("sjd_http_requests").inc();
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/healthz") => write_response(&mut stream, 200, "text/plain", b"ok"),
            ("GET", "/metrics") => {
                let text = self.registry.render_text();
                write_response(&mut stream, 200, "text/plain", text.as_bytes())
            }
            ("POST", "/generate") => match parse_generate_body(&req.body) {
                // Malformed request: the client's fault.
                Err(e) => {
                    self.registry.counter("sjd_http_errors").inc();
                    write_response(&mut stream, 400, "application/json", error_json(&e).as_bytes())
                }
                Ok((n, seed)) => match self.generate(n, seed) {
                    Ok(json) => {
                        write_response(&mut stream, 200, "application/json", json.as_bytes())
                    }
                    // Internal failure (batcher, encode, ...): ours.
                    Err(e) => {
                        self.registry.counter("sjd_http_errors").inc();
                        write_response(
                            &mut stream,
                            500,
                            "application/json",
                            error_json(&e).as_bytes(),
                        )
                    }
                },
            },
            _ => write_response(&mut stream, 404, "text/plain", b"not found"),
        }
    }

    fn generate(&self, n: usize, seed: u64) -> Result<String> {
        let rid = self.next_request_id.fetch_add(1, Ordering::SeqCst);

        // Submit n slots and wait for completion.
        let handles: Vec<_> =
            (0..n).map(|i| self.batcher.submit(rid, seed.wrapping_add(i as u64))).collect();
        let mut pngs = Vec::with_capacity(n);
        for h in handles {
            let img_t = h.wait();
            let img = Image::from_tensor_pm1(&img_t)?;
            let png = imageio::encode_png(&img)?;
            pngs.push(Value::Str(base64_encode(&png)));
        }
        let resp = Value::obj(vec![
            ("request_id", Value::num(rid as f64)),
            ("n", Value::num(n as f64)),
            ("images_png_b64", Value::Arr(pngs)),
        ]);
        Ok(jsonx::to_string_pretty(&resp))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base64_vectors() {
        // RFC 4648 test vectors.
        assert_eq!(base64_encode(b""), "");
        assert_eq!(base64_encode(b"f"), "Zg==");
        assert_eq!(base64_encode(b"fo"), "Zm8=");
        assert_eq!(base64_encode(b"foo"), "Zm9v");
        assert_eq!(base64_encode(b"foobar"), "Zm9vYmFy");
    }

    #[test]
    fn parse_simple_request() {
        let raw = b"POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: 7\r\n\r\n{\"n\":2}";
        let mut r = std::io::BufReader::new(&raw[..]);
        let req = parse_request(&mut r).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/generate");
        assert_eq!(req.body, b"{\"n\":2}");
    }

    #[test]
    fn parse_request_without_body() {
        let raw = b"GET /healthz HTTP/1.1\r\n\r\n";
        let mut r = std::io::BufReader::new(&raw[..]);
        let req = parse_request(&mut r).unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
    }

    #[test]
    fn rejects_bad_version_and_eof() {
        let raw = b"GET / SPDY/3\r\n\r\n";
        let mut r = std::io::BufReader::new(&raw[..]);
        assert!(parse_request(&mut r).is_err());
        let mut empty = std::io::BufReader::new(&b""[..]);
        assert!(parse_request(&mut empty).is_err());
    }

    #[test]
    fn rejects_header_flood() {
        // More headers than MAX_HEADERS, each small: must error, not loop
        // buffering forever.
        let mut raw = String::from("GET / HTTP/1.1\r\n");
        for i in 0..(MAX_HEADERS + 10) {
            raw.push_str(&format!("X-H{i}: v\r\n"));
        }
        raw.push_str("\r\n");
        let mut r = std::io::BufReader::new(raw.as_bytes());
        let err = parse_request(&mut r).unwrap_err().to_string();
        assert!(err.contains("too many headers"), "{err}");
    }

    #[test]
    fn rejects_oversized_header_section() {
        // One giant header line past the byte budget.
        let mut raw = String::from("GET / HTTP/1.1\r\nX-Big: ");
        raw.push_str(&"a".repeat(MAX_HEADER_BYTES + 1024));
        raw.push_str("\r\n\r\n");
        let mut r = std::io::BufReader::new(raw.as_bytes());
        assert!(parse_request(&mut r).is_err());
    }

    #[test]
    fn rejects_unterminated_header_line() {
        // A header that never ends (no newline at all): the cap must fire
        // even though read_line would otherwise buffer indefinitely.
        let mut raw = String::from("GET / HTTP/1.1\r\n");
        raw.push_str(&"b".repeat(MAX_HEADER_BYTES + 4096));
        let mut r = std::io::BufReader::new(raw.as_bytes());
        assert!(parse_request(&mut r).is_err());
    }

    #[test]
    fn header_budget_counts_request_line() {
        // Exhaust the budget with the request line itself (long path).
        let mut raw = String::from("GET /");
        raw.push_str(&"p".repeat(MAX_HEADER_BYTES + 16));
        raw.push_str(" HTTP/1.1\r\n\r\n");
        let mut r = std::io::BufReader::new(raw.as_bytes());
        assert!(parse_request(&mut r).is_err());
    }

    #[test]
    fn error_json_stays_valid_with_quotes_and_backslashes() {
        let err = anyhow::anyhow!("bad \"json\" in C:\\path\nline2");
        let body = error_json(&err);
        let parsed = jsonx::parse(&body).expect("error body must be valid JSON");
        assert_eq!(
            parsed.get("error").and_then(Value::as_str),
            Some("bad \"json\" in C:\\path\nline2")
        );
    }

    #[test]
    fn parse_generate_body_defaults_and_errors() {
        assert_eq!(parse_generate_body(b"").unwrap(), (1, 0));
        assert_eq!(parse_generate_body(br#"{"n": 3, "seed": 9}"#).unwrap(), (3, 9));
        // Clamped to [1, 64].
        assert_eq!(parse_generate_body(br#"{"n": 1000}"#).unwrap().0, 64);
        assert!(parse_generate_body(b"{invalid").is_err());
        assert!(parse_generate_body(&[0xff, 0xfe]).is_err());
    }

    #[test]
    fn response_format() {
        let mut buf = Vec::new();
        write_response(&mut buf, 200, "text/plain", b"hi").unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(s.contains("Content-Length: 2\r\n"));
        assert!(s.ends_with("\r\n\r\nhi"));
    }
}
