"""AOT lowering: train (or load cached) weights, lower every artifact to HLO
**text**, and write ``artifacts/manifest.json``.

HLO text — not serialized HloModuleProto — is the interchange format: jax
≥ 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md and DESIGN.md §2).

Usage:
    python -m compile.aot --out-dir ../artifacts [--only tf10,maf_ising]
                          [--force-retrain] [--quick]
"""

import argparse
import functools
import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import baselines, maf, metricnet, tarflow, train

# ---------------------------------------------------------------------------
# Model zoo (paper-table mapping in DESIGN.md §4-5)
# ---------------------------------------------------------------------------

TARFLOW_MODELS = {
    # CIFAR-10 stand-in: L = 64 tokens.
    "tf10": tarflow.TarFlowConfig(
        name="tf10", img_hw=16, channels=3, patch=2, blocks=4,
        layers_per_block=2, model_dim=64, heads=4, noise_std=0.05,
        dataset="synth10", train_steps=700, train_batch=64, lr=1e-3),
    # CIFAR-100 stand-in.
    "tf100": tarflow.TarFlowConfig(
        name="tf100", img_hw=16, channels=3, patch=2, blocks=4,
        layers_per_block=2, model_dim=64, heads=4, noise_std=0.05,
        dataset="synth100", train_steps=700, train_batch=64, lr=1e-3),
    # AFHQ stand-in: the large-L regime (L = 256 tokens). Its experimental
    # role is the UJD-vs-SJD timing asymmetry at long sequence length, so the
    # training budget is kept small (single-core CPU testbed).
    "tfafhq": tarflow.TarFlowConfig(
        name="tfafhq", img_hw=32, channels=3, patch=2, blocks=4,
        layers_per_block=2, model_dim=96, heads=4, noise_std=0.05,
        dataset="synthafhq", train_steps=150, train_batch=16, lr=7e-4),
}

MAF_MODELS = {
    # 8×8 Ising lattice at T = 3.0 (Table A5).
    "maf_ising": maf.MafConfig(
        name="maf_ising", dim=64, layers=8, hidden=128,
        dataset="ising", train_steps=800, train_batch=256, lr=1e-3),
    # Binary digit images (Fig A3).
    "maf_img": maf.MafConfig(
        name="maf_img", dim=196, layers=5, hidden=256,
        dataset="digits", train_steps=500, train_batch=128, lr=1e-3),
}

DDPM_CFG = baselines.DdpmConfig(
    name="ddpm", img_hw=16, channels=3, hidden=48, timesteps=200,
    dataset="synth10", train_steps=400, train_batch=64, lr=1e-3)

MMDGEN_CFG = baselines.MmdGenConfig(
    name="mmdgen", img_hw=16, channels=3, z_dim=64, hidden=64,
    dataset="synth10", train_steps=300, train_batch=64, lr=1e-3)

# Batch sizes to lower per model family. Each tarflow batch size becomes one
# serving *bucket*: the full per-batch artifact family
# (fwd/block_fwd/jstep/jstep_win/seqfull/seqstep/reverse) is lowered per
# bucket, and the rust router dispatches each formed batch to the smallest
# bucket covering it (`Manifest::decode_buckets` groups them back). Override
# with --batch-sizes, e.g. `--batch-sizes 1,2,4,8` for fine-grained serving.
TF_BATCHES = {"tf10": [1, 8], "tf100": [1, 8], "tfafhq": [1, 4]}
MAF_BATCHES = {"maf_ising": [256], "maf_img": [50]}

# Static residual-history length of the fused multi-step Jacobi artifacts
# (`{m}_block_jstep_fuse_b{B}` / `{m}_block_jstep_win_fuse_b{B}`): each call
# runs up to this many updates on device and returns one (S, B) residual
# history, so the rust chunk scheduler syncs once per chunk instead of once
# per iteration. The rust side discovers the cap from the output shape.
JSTEP_FUSE_STEPS = 8


def parse_batch_sizes(spec: str):
    """Parse a `--batch-sizes` list ("1,2,4,8") into sorted unique buckets.

    Empty/whitespace spec → None (use the per-model defaults above).
    """
    parts = [p.strip() for p in spec.split(",") if p.strip()]
    if not parts:
        return None
    sizes = set()
    for p in parts:
        try:
            b = int(p)
        except ValueError:
            raise ValueError(f"bad bucket size {p!r} in --batch-sizes") from None
        if b < 1:
            raise ValueError(f"bucket sizes must be >= 1, got {b}")
        sizes.add(b)
    return sorted(sizes)


# ---------------------------------------------------------------------------
# Lowering plumbing
# ---------------------------------------------------------------------------

def to_hlo_text(lowered, return_tuple=True) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple)
    # print_large_constants=True is load-bearing: the default printer elides
    # big constants as `constant({...})`, which would silently strip the
    # baked model weights from the artifact.
    return comp.as_hlo_text(print_large_constants=True)


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


I32 = jnp.int32


class ArtifactWriter:
    def __init__(self, out_dir: pathlib.Path):
        self.out_dir = out_dir
        self.entries = []
        self.models = []
        self.datasets = []

    def lower(self, name, fn, in_specs, in_names, model=None, untupled=False):
        """Trace `fn` at `in_specs`, write HLO text, record manifest entry.

        ``untupled=True`` lowers with ``return_tuple=False`` (single-output
        programs only): the HLO root is the bare array, so the rust engine
        can keep the result buffer device-resident with no leaf-vs-tuple
        ambiguity (see ``Engine::call_v``).
        """
        t0 = time.time()
        lowered = jax.jit(fn).lower(*[spec(s, d) for s, d in in_specs])
        text = to_hlo_text(lowered, return_tuple=not untupled)
        fname = f"{name}.hlo.txt"
        (self.out_dir / fname).write_text(text)
        # Output signature from the traced result.
        out_tree = lowered.out_info
        outs = jax.tree_util.tree_leaves(out_tree)
        if untupled and len(outs) != 1:
            raise ValueError(f"{name}: untupled lowering requires exactly 1 output")
        entry = {
            "name": name,
            "file": fname,
            "model": model,
            "untupled_outputs": untupled,
            "inputs": [
                {"name": n, "dtype": _dtype_str(d), "shape": list(s)}
                for (s, d), n in zip(in_specs, in_names)
            ],
            "outputs": [
                {"name": f"out{i}", "dtype": _dtype_str(o.dtype), "shape": list(o.shape)}
                for i, o in enumerate(outs)
            ],
        }
        self.entries.append(entry)
        print(f"  lowered {name}: {len(text) / 1e6:.2f} MB HLO in {time.time() - t0:.1f}s",
              flush=True)

    def add_model(self, meta: dict):
        self.models.append(meta)

    def add_dataset(self, name: str, array, extra=None):
        """Write a reference sample set as raw little-endian f32 for the rust
        quality benches (FID real-side statistics)."""
        import numpy as np
        arr = np.ascontiguousarray(np.asarray(array, dtype=np.float32))
        fname = f"data_{name}.f32"
        (self.out_dir / fname).write_bytes(arr.tobytes())
        self.datasets.append({
            "name": name, "file": fname, "shape": list(arr.shape),
            "extra": extra or {},
        })
        print(f"  dataset {name}: shape {list(arr.shape)}", flush=True)

    def write_manifest(self):
        manifest = {"artifacts": self.entries, "models": self.models,
                    "datasets": self.datasets}
        (self.out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
        print(f"wrote manifest with {len(self.entries)} artifacts, "
              f"{len(self.models)} models, {len(self.datasets)} datasets", flush=True)


def _dtype_str(d):
    d = jnp.dtype(d)
    if d == jnp.float32:
        return "f32"
    if d == jnp.int32:
        return "i32"
    raise ValueError(f"unsupported dtype {d}")


# ---------------------------------------------------------------------------
# Per-family artifact lowering
# ---------------------------------------------------------------------------

def lower_tarflow(w: ArtifactWriter, cfg: tarflow.TarFlowConfig, params, batches):
    L, D = cfg.seq_len, cfg.token_dim
    NL, DM = cfg.layers_per_block, cfg.model_dim
    hw, c = cfg.img_hw, cfg.channels

    for b in batches:
        w.lower(
            f"{cfg.name}_fwd_b{b}",
            lambda x: tarflow.flow_forward(params, cfg, x, use_pallas=True),
            [((b, hw, hw, c), jnp.float32)],
            ["x"],
            model=cfg.name,
        )
        w.lower(
            f"{cfg.name}_block_fwd_b{b}",
            lambda k, u: tarflow.block_forward(params, cfg, k, u, use_pallas=True)[0],
            [((), I32), ((b, L, D), jnp.float32)],
            ["k", "u"],
            model=cfg.name,
        )
        w.lower(
            f"{cfg.name}_block_jstep_b{b}",
            lambda k, z, y, o: tarflow.block_jacobi_step(
                params, cfg, k, z, y, o, use_pallas=True),
            [((), I32), ((b, L, D), jnp.float32), ((b, L, D), jnp.float32), ((), I32)],
            ["k", "z_prev", "y", "o"],
            model=cfg.name,
        )
        # Windowed GS-Jacobi inner step: like block_jstep but only positions
        # in [off, off+len) move and the residual covers the window only —
        # the rust coordinator sweeps windows Gauss–Seidel-style
        # (gs_jacobi_decode_block_v) so later windows condition on converged
        # prefixes. Optional: older drivers probe via Backend::has_artifact
        # and fall back to the full-sequence jstep.
        w.lower(
            f"{cfg.name}_block_jstep_win_b{b}",
            lambda k, z, y, off, wl: tarflow.block_jacobi_step_window(
                params, cfg, k, z, y, off, wl, use_pallas=True),
            [((), I32), ((b, L, D), jnp.float32), ((b, L, D), jnp.float32),
             ((), I32), ((), I32)],
            ["k", "z_prev", "y", "off", "len"],
            model=cfg.name,
        )
        # Fused multi-step Jacobi: a lax.fori_loop over the jstep body that
        # runs up to `steps` updates on device and records the residual
        # after each — one dispatch + one (S, B) sync per *chunk* instead of
        # per iteration (the rust chunk scheduler recovers exact τ-stopping
        # semantics by scanning the history host-side). Optional role:
        # Manifest::decode_buckets treats its absence as "no fused path",
        # and the rust Sampler falls back to the per-step artifact.
        w.lower(
            f"{cfg.name}_block_jstep_fuse_b{b}",
            lambda k, z, y, steps: tarflow.block_jacobi_multi_step(
                params, cfg, k, z, y, steps, JSTEP_FUSE_STEPS, use_pallas=True),
            [((), I32), ((b, L, D), jnp.float32), ((b, L, D), jnp.float32),
             ((), I32)],
            ["k", "z_prev", "y", "steps"],
            model=cfg.name,
        )
        # Windowed fused multi-step: the GS-Jacobi inner loop chunked the
        # same way, window pinned per call.
        w.lower(
            f"{cfg.name}_block_jstep_win_fuse_b{b}",
            lambda k, z, y, steps, off, wl: tarflow.block_jacobi_multi_step_window(
                params, cfg, k, z, y, steps, off, wl, JSTEP_FUSE_STEPS,
                use_pallas=True),
            [((), I32), ((b, L, D), jnp.float32), ((b, L, D), jnp.float32),
             ((), I32), ((), I32), ((), I32)],
            ["k", "z_prev", "y", "steps", "off", "len"],
            model=cfg.name,
        )
        # Speculative-init projection: truncated conditioner + one affine
        # extrapolation predicting a z⁰ for the Jacobi solve from the block
        # input alone. Optional role (like the fused family): drivers that
        # don't find it start from zeros. Untupled so the prediction chains
        # straight into the jstep inputs with zero host traffic — the
        # speculative path must never round-trip through the CPU.
        w.lower(
            f"{cfg.name}_init_proj_b{b}",
            lambda k, y: tarflow.block_init_proj(params, cfg, k, y, use_pallas=True),
            [((), I32), ((b, L, D), jnp.float32)],
            ["k", "y"],
            model=cfg.name,
            untupled=True,
        )
        w.lower(
            f"{cfg.name}_block_seqfull_b{b}",
            lambda k, v: (tarflow.block_seq_full(params, cfg, k, v),),
            [((), I32), ((b, L, D), jnp.float32)],
            ["k", "v"],
            model=cfg.name,
        )
        # Device-side inter-block permutation P_k (token reversal): lets the
        # rust coordinator chain block outputs device→device without the
        # host-fallback sync point (see Sampler::reverse_tokens_v). Lowered
        # untupled so the output buffer is a chainable leaf.
        w.lower(
            f"{cfg.name}_reverse_b{b}",
            lambda t: jnp.flip(t, axis=1),
            [((b, L, D), jnp.float32)],
            ["t"],
            model=cfg.name,
            untupled=True,
        )
        # Device-side slot remap for continuous batching (`serve --refill`):
        # gathers whole batch rows by index so a wave that lost slots at a
        # block boundary compacts live rows to the front (pad indices
        # re-point at row 0) without a host round-trip, then migrates to a
        # smaller covering bucket. Optional role, untupled like `reverse` so
        # the remapped tokens chain straight into the next block's inputs.
        w.lower(
            f"{cfg.name}_slot_gather_b{b}",
            lambda t, idx: t[idx],
            [((b, L, D), jnp.float32), ((b,), I32)],
            ["t", "idx"],
            model=cfg.name,
            untupled=True,
        )
        w.lower(
            f"{cfg.name}_block_seqstep_b{b}",
            lambda k, up, vt, pos, kk, kv: tarflow.block_seq_step(
                params, cfg, k, up, vt, pos, kk, kv),
            [((), I32), ((b, D), jnp.float32), ((b, D), jnp.float32), ((), I32),
             ((NL, b, L, DM), jnp.float32), ((NL, b, L, DM), jnp.float32)],
            ["k", "u_prev", "v_tok", "pos", "kv_k", "kv_v"],
            model=cfg.name,
        )

    w.add_model({
        "name": cfg.name,
        "kind": "tarflow",
        "seq_len": L,
        "blocks": cfg.blocks,
        "token_dim": D,
        "model_dim": DM,
        "layers_per_block": NL,
        "image_hwc": [hw, hw, c],
        "patch": cfg.patch,
        "noise_std": cfg.noise_std,
        "batch_sizes": batches,
        "extra": {"dataset": cfg.dataset, "heads": cfg.heads,
                  "params": tarflow.param_count(params)},
    })


def lower_maf(w: ArtifactWriter, cfg: maf.MafConfig, params, batches):
    d = cfg.dim
    for b in batches:
        w.lower(
            f"{cfg.name}_fwd_b{b}",
            lambda x: maf.flow_forward(params, cfg, x),
            [((b, d), jnp.float32)],
            ["x"],
            model=cfg.name,
        )
        w.lower(
            f"{cfg.name}_layer_jstep_b{b}",
            lambda k, z, y: maf.layer_jacobi_step(params, cfg, k, z, y),
            [((), I32), ((b, d), jnp.float32), ((b, d), jnp.float32)],
            ["k", "z_prev", "y"],
            model=cfg.name,
        )
    w.add_model({
        "name": cfg.name,
        "kind": "maf",
        "seq_len": d,
        "blocks": cfg.layers,
        "token_dim": 1,
        "model_dim": cfg.hidden,
        "layers_per_block": 0,
        "image_hwc": None,
        "patch": 1,
        "noise_std": 0.0,
        "batch_sizes": batches,
        "extra": {"dataset": cfg.dataset},
    })


def lower_metricnet(w: ArtifactWriter, name: str, img_hw: int, batches):
    cfg = metricnet.MetricNetConfig(name=name, img_hw=img_hw)
    params = metricnet.init_params(cfg)
    for b in batches:
        w.lower(
            f"{name}_feat_b{b}",
            lambda x: (metricnet.features(params, x),),
            [((b, img_hw, img_hw, 3), jnp.float32)],
            ["x"],
            model=name,
        )
    w.add_model({
        "name": name, "kind": "metricnet", "seq_len": 0, "blocks": 0,
        "token_dim": 3, "model_dim": cfg.features, "layers_per_block": 0,
        "image_hwc": [img_hw, img_hw, 3], "patch": 1, "noise_std": 0.0,
        "batch_sizes": batches, "extra": {},
    })


def lower_ddpm(w: ArtifactWriter, cfg: baselines.DdpmConfig, params, batches):
    hw, c = cfg.img_hw, cfg.channels
    for b in batches:
        w.lower(
            f"{cfg.name}_eps_b{b}",
            lambda x, t: (baselines.eps_model(params, x, t),),
            [((b, hw, hw, c), jnp.float32), ((), I32)],
            ["x", "t"],
            model=cfg.name,
        )
    w.add_model({
        "name": cfg.name, "kind": "ddpm", "seq_len": 0, "blocks": cfg.timesteps,
        "token_dim": c, "model_dim": cfg.hidden, "layers_per_block": 0,
        "image_hwc": [hw, hw, c], "patch": 1, "noise_std": 0.0,
        "batch_sizes": batches, "extra": {"timesteps": cfg.timesteps},
    })


def lower_mmdgen(w: ArtifactWriter, cfg: baselines.MmdGenConfig, params, batches):
    hw, c = cfg.img_hw, cfg.channels
    for b in batches:
        w.lower(
            f"{cfg.name}_gen_b{b}",
            lambda z: (baselines.generator(params, cfg, z),),
            [((b, cfg.z_dim), jnp.float32)],
            ["z"],
            model=cfg.name,
        )
    w.add_model({
        "name": cfg.name, "kind": "mmdgen", "seq_len": 0, "blocks": 0,
        "token_dim": c, "model_dim": cfg.hidden, "layers_per_block": 0,
        "image_hwc": [hw, hw, c], "patch": 1, "noise_std": 0.0,
        "batch_sizes": batches, "extra": {"z_dim": cfg.z_dim},
    })


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default="",
                    help="comma-separated model names (default: all)")
    ap.add_argument("--force-retrain", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="slash train steps 10x (CI / smoke use)")
    ap.add_argument("--batch-sizes", default="",
                    help="comma-separated decode buckets lowered per tarflow "
                         "model, e.g. 1,2,4,8 (default: per-model table)")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir).resolve()
    out_dir.mkdir(parents=True, exist_ok=True)
    weights_dir = out_dir / "weights"
    only = set(filter(None, args.only.split(",")))
    tf_buckets = parse_batch_sizes(args.batch_sizes)

    def want(name):
        return not only or name in only

    def quick(cfg):
        if not args.quick:
            return cfg
        return cfg._replace(train_steps=max(30, cfg.train_steps // 10))

    w = ArtifactWriter(out_dir)
    t_start = time.time()

    for name, cfg in TARFLOW_MODELS.items():
        if not want(name):
            continue
        cfg = quick(cfg)
        loss_log = []
        params = train.train_or_load(
            name, weights_dir,
            lambda cfg=cfg, ll=loss_log: train.train_tarflow(cfg, loss_log=ll),
            force=args.force_retrain)
        if loss_log:
            (out_dir / f"{name}_train_loss.json").write_text(json.dumps(loss_log))
        lower_tarflow(w, cfg, params, tf_buckets or TF_BATCHES[name])

    for name, cfg in MAF_MODELS.items():
        if not want(name):
            continue
        cfg = quick(cfg)
        loss_log = []
        params = train.train_or_load(
            name, weights_dir,
            lambda cfg=cfg, ll=loss_log: train.train_maf(cfg, loss_log=ll),
            force=args.force_retrain)
        if loss_log:
            (out_dir / f"{name}_train_loss.json").write_text(json.dumps(loss_log))
        lower_maf(w, cfg, params, MAF_BATCHES[name])

    if want("metricnet16"):
        lower_metricnet(w, "metricnet16", 16, [64])
    if want("metricnet32"):
        lower_metricnet(w, "metricnet32", 32, [32])

    if want("ddpm"):
        cfg = quick(DDPM_CFG)
        params = train.train_or_load(
            "ddpm", weights_dir, lambda: train.train_ddpm(cfg), force=args.force_retrain)
        lower_ddpm(w, cfg, params, [8])
    if want("mmdgen"):
        cfg = quick(MMDGEN_CFG)
        params = train.train_or_load(
            "mmdgen", weights_dir, lambda: train.train_mmdgen(cfg), force=args.force_retrain)
        lower_mmdgen(w, cfg, params, [8])

    # Reference sample sets for the rust quality benches.
    if want("datasets"):
        from . import data as data_mod
        from . import ising as ising_mod
        for ds_name, n in [("synth10", 512), ("synth100", 512), ("synthafhq", 256)]:
            ds = data_mod.make_dataset(ds_name)
            w.add_dataset(ds_name, ds.batch(n, seed=123))
        digits = data_mod.make_dataset("digits")
        w.add_dataset("digits", digits.batch(512, seed=123))
        ids = ising_mod.IsingDataset(side=8, temperature=3.0, n_configs=1024, seed=11)
        e_ref, m_ref = ids.reference_stats()
        w.add_dataset("ising_ref", ids.configs[:512],
                      extra={"energy_per_site": e_ref, "abs_magnetization": m_ref,
                             "side": 8, "temperature": 3.0})

    w.write_manifest()
    print(f"artifacts complete in {time.time() - t_start:.0f}s → {out_dir}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
