//! Deterministic structure-aware fuzzing (cargo-fuzz substitute — crates.io
//! and libFuzzer are unreachable in this environment, so the fuzz sweeps
//! run *inside* `cargo test -q` instead of as a separate fuzz target).
//!
//! The model is classic mutation-based fuzzing: start from a small corpus
//! of well-formed inputs, apply a random stack of byte- and token-level
//! mutations (bit flips, splices, truncations, duplications, dictionary
//! token insertion), and feed each mutant to the system under test. The
//! PRNG is the repo's own seeded [`Pcg64`], so every sweep is exactly
//! reproducible from its `(seed, case)` pair — a failing case panics with
//! both, and re-running the test replays it.
//!
//! The harness checks *robustness*, not correctness: the property closure
//! must not panic (parse-or-reject); any stronger invariant (round-trip,
//! caps) is the caller's to assert inside the closure.

use crate::tensor::Pcg64;

/// Hard bound on a mutant's size, so duplication stacking can't balloon a
/// case into allocation-bound territory.
const MAX_CASE_BYTES: usize = 1 << 16;

/// A seeded corpus mutator: byte- and token-level transforms over an input.
pub struct Mutator<'a> {
    rng: Pcg64,
    /// Interesting tokens spliced in whole (header names, keywords,
    /// boundary numerals) — this is what makes the fuzzing structure-aware
    /// rather than pure byte soup.
    dict: &'a [&'a [u8]],
}

impl<'a> Mutator<'a> {
    pub fn new(seed: u64, dict: &'a [&'a [u8]]) -> Self {
        Mutator { rng: Pcg64::seed(seed), dict }
    }

    /// Apply 1..=8 random mutations to `base` and return the mutant.
    pub fn mutate(&mut self, base: &[u8]) -> Vec<u8> {
        let mut v = base.to_vec();
        let rounds = 1 + self.rng.next_below(8);
        for _ in 0..rounds {
            self.mutate_once(&mut v);
        }
        v.truncate(MAX_CASE_BYTES);
        v
    }

    fn mutate_once(&mut self, v: &mut Vec<u8>) {
        match self.rng.next_below(8) {
            // Flip one bit.
            0 if !v.is_empty() => {
                let i = self.rng.next_below(v.len());
                v[i] ^= 1 << self.rng.next_below(8);
            }
            // Overwrite one byte with an interesting value.
            1 if !v.is_empty() => {
                const INTERESTING: &[u8] = &[0, 1, 9, 10, 13, 32, 58, 127, 128, 255];
                let i = self.rng.next_below(v.len());
                v[i] = INTERESTING[self.rng.next_below(INTERESTING.len())];
            }
            // Truncate at a random point.
            2 if !v.is_empty() => {
                let i = self.rng.next_below(v.len());
                v.truncate(i);
            }
            // Delete a random span.
            3 if !v.is_empty() => {
                let a = self.rng.next_below(v.len());
                let b = (a + 1 + self.rng.next_below(16)).min(v.len());
                v.drain(a..b);
            }
            // Duplicate a random span in place.
            4 if !v.is_empty() => {
                let a = self.rng.next_below(v.len());
                let b = (a + 1 + self.rng.next_below(32)).min(v.len());
                let span: Vec<u8> = v[a..b].to_vec();
                let at = self.rng.next_below(v.len() + 1);
                v.splice(at..at, span);
            }
            // Insert a dictionary token (the structure-aware move).
            5 if !self.dict.is_empty() => {
                let tok = self.dict[self.rng.next_below(self.dict.len())];
                let at = self.rng.next_below(v.len() + 1);
                v.splice(at..at, tok.iter().copied());
            }
            // Insert 1..=8 random bytes.
            6 => {
                let at = self.rng.next_below(v.len() + 1);
                let n = 1 + self.rng.next_below(8);
                let bytes: Vec<u8> = (0..n).map(|_| self.rng.next_below(256) as u8).collect();
                v.splice(at..at, bytes);
            }
            // Swap two random bytes.
            _ if v.len() >= 2 => {
                let i = self.rng.next_below(v.len());
                let j = self.rng.next_below(v.len());
                v.swap(i, j);
            }
            _ => {}
        }
    }
}

/// Run `n` fuzz cases against `f`: each case is a corpus entry (cases cycle
/// through the corpus so every seed input is exercised) mutated by a
/// seeded [`Mutator`]. The first ~corpus-length cases are the *unmutated*
/// corpus itself, so a harness that can't even handle its own well-formed
/// seeds fails immediately and obviously. A panic inside `f` is caught and
/// re-raised with the `(seed, case)` pair and a byte dump of the mutant, so
/// any failure is replayable.
pub fn fuzz_cases(
    corpus: &[&[u8]],
    dict: &[&[u8]],
    n: usize,
    seed: u64,
    f: impl Fn(&[u8]) + std::panic::RefUnwindSafe,
) {
    assert!(!corpus.is_empty(), "fuzz corpus must not be empty");
    let mut mutator = Mutator::new(seed, dict);
    for case in 0..n {
        let base = corpus[case % corpus.len()];
        let input = if case < corpus.len() { base.to_vec() } else { mutator.mutate(base) };
        let r = std::panic::catch_unwind(|| f(&input));
        if let Err(payload) = r {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "fuzz case panicked (seed {seed:#x}, case {case}/{n})\n  panic: {msg}\n  input ({} bytes): {:?}",
                input.len(),
                String::from_utf8_lossy(&input)
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mutations_are_deterministic_per_seed() {
        let dict: &[&[u8]] = &[b"tok", b"\r\n"];
        let mut a = Mutator::new(42, dict);
        let mut b = Mutator::new(42, dict);
        for _ in 0..100 {
            assert_eq!(a.mutate(b"hello world"), b.mutate(b"hello world"));
        }
        // A different seed diverges somewhere within a few cases.
        let mut c = Mutator::new(43, dict);
        let mut a = Mutator::new(42, dict);
        let diverged = (0..10).any(|_| a.mutate(b"hello world") != c.mutate(b"hello world"));
        assert!(diverged);
    }

    #[test]
    fn mutants_stay_bounded() {
        let mut m = Mutator::new(7, &[b"AAAAAAAAAAAAAAAA"]);
        let base = vec![b'x'; 1024];
        for _ in 0..1000 {
            assert!(m.mutate(&base).len() <= MAX_CASE_BYTES);
        }
    }

    #[test]
    fn fuzz_cases_replays_corpus_first_and_reports_failures() {
        // The unmutated corpus is always fed through first.
        let seen = std::sync::Mutex::new(Vec::new());
        fuzz_cases(&[b"alpha", b"beta"], &[], 10, 1, |case| {
            seen.lock().unwrap().push(case.to_vec());
        });
        let seen = seen.lock().unwrap();
        assert_eq!(&seen[0], b"alpha");
        assert_eq!(&seen[1], b"beta");
        assert_eq!(seen.len(), 10);

        // A panicking property surfaces as a replayable report.
        let r = std::panic::catch_unwind(|| {
            fuzz_cases(&[b"x"], &[], 5, 9, |_case| panic!("boom"));
        });
        let msg = match r {
            Err(p) => p.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(()) => panic!("expected failure"),
        };
        assert!(msg.contains("seed 0x9") || msg.contains("seed 9"), "{msg}");
        assert!(msg.contains("case"), "{msg}");
    }
}
