//! **Table A4**: per-layer runtime breakdown, sequential vs SJD. Under SJD
//! the sequential layer 1 dominates total cost; Jacobi layers complete in a
//! fraction of the per-layer sequential time. "Other" = noise generation,
//! permutations, unpatchify.

mod common;

use common::*;
use sjd::benchkit::Report;
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::sampler::Sampler;

fn main() -> anyhow::Result<()> {
    let engine = engine_or_skip();
    let model = if engine.manifest().model("tfafhq").is_ok() { "tfafhq" } else { "tf10" };
    let batch = *engine.manifest().model(model)?.batch_sizes.iter().max().unwrap();
    let sampler = Sampler::new(&engine, model, batch)?;
    let kk = sampler.meta.blocks;
    let reps = if quick() { 1 } else { 3 };

    let mut report = Report::new(format!("Table A4 — per-layer runtime breakdown ({model})"));
    let mut rows = Vec::new();

    let mut data: Vec<(String, Vec<f64>, f64)> = Vec::new();
    for policy in [DecodePolicy::Sequential, DecodePolicy::Selective { seq_blocks: 1 }] {
        let label = policy.label();
        let _ = generate(&sampler, policy.clone(), 0.5, batch, 1)?; // warmup
        let run = generate(&sampler, policy.clone(), 0.5, batch * reps, 42)?;
        let per_layer: Vec<f64> =
            (0..kk).map(|p| mean_f64(&run.per_position_wall[p])).collect();
        let other = run.other_wall / run.batches as f64;
        data.push((label, per_layer, other));
    }

    for pos in 0..kk {
        let mut row = vec![format!("Layer {}", pos + 1)];
        for (_, per_layer, _) in &data {
            row.push(format!("{:.3}", per_layer[pos]));
        }
        rows.push(row);
    }
    let mut other_row = vec!["Other".to_string()];
    let mut total_row = vec!["Total".to_string()];
    for (_, per_layer, other) in &data {
        other_row.push(format!("{other:.3}"));
        total_row.push(format!("{:.3}", per_layer.iter().sum::<f64>() + other));
    }
    rows.push(other_row);
    rows.push(total_row);

    let header: Vec<String> = std::iter::once("Component".to_string())
        .chain(data.iter().map(|(l, _, _)| format!("{l} (s)")))
        .collect();
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    report.table(&header_refs, &rows);
    report.note("Paper shape: sequential layers all cost ≈ the same; under SJD layer 1 dominates and Jacobi layers are cheap.");
    report.finish();
    Ok(())
}
