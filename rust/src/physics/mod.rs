//! 2-D Ising model substrate (Table A5): energy / magnetization observables
//! for flow samples, plus a Metropolis MCMC reference sampler that provides
//! the ground-truth disordered-state statistics at T = 3.0.

mod ising;

pub use ising::{IsingModel, IsingStats};
