//! Minimal blocking HTTP/1.1 response reader, shared by the serving
//! integration tests and the load bench so the framing logic lives once.

use std::io::{BufRead, Read};

/// Read exactly one HTTP response (status line + headers + content-length
/// body) off a buffered stream, leaving it usable for keep-alive reuse.
/// Returns `(head, body)`: the status line + headers verbatim, and the raw
/// body bytes.
pub fn read_response(reader: &mut impl BufRead) -> std::io::Result<(String, Vec<u8>)> {
    let mut head = String::new();
    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            break; // EOF mid-head: return what we have, body length 0
        }
        if let Some((k, v)) = line.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
        let done = line.trim_end().is_empty();
        head.push_str(&line);
        if done {
            break;
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok((head, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    #[test]
    fn reads_one_response_and_leaves_the_rest() {
        let raw = b"HTTP/1.1 200 OK\r\nContent-Length: 2\r\n\r\nhiHTTP/1.1 404";
        let mut r = BufReader::new(&raw[..]);
        let (head, body) = read_response(&mut r).unwrap();
        assert!(head.starts_with("HTTP/1.1 200"));
        assert!(head.ends_with("\r\n\r\n"));
        assert_eq!(body, b"hi");
        // The next response's bytes are still in the stream.
        let mut rest = String::new();
        r.read_to_string(&mut rest).unwrap();
        assert_eq!(rest, "HTTP/1.1 404");
    }

    #[test]
    fn no_body_without_content_length() {
        let raw = b"HTTP/1.1 404 Not Found\r\n\r\n";
        let mut r = BufReader::new(&raw[..]);
        let (head, body) = read_response(&mut r).unwrap();
        assert!(head.starts_with("HTTP/1.1 404"));
        assert!(body.is_empty());
    }
}
