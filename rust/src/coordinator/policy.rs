//! Decode-policy selection (paper §3.5, "Where to Use Jacobi Decoding").
//!
//! The flow has `K` blocks decoded in order `k = K, K−1, …, 1` during
//! sampling (noise → data). Block index here is the *decode position*
//! `0 .. K-1` where position 0 is the first block applied to Gaussian noise —
//! the paper's "first layer" with low redundancy.

use super::jacobi::JacobiStats;

/// How each of the `K` blocks is decoded.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DecodePolicy {
    /// Standard sequential (autoregressive, KV cache) everywhere — the
    /// paper's baseline.
    Sequential,
    /// Jacobi everywhere (paper's "UJD" baseline).
    UniformJacobi,
    /// Paper's SJD: sequential for the first `seq_blocks` decode positions,
    /// Jacobi for the rest. `seq_blocks = 1` is the paper's setting.
    Selective { seq_blocks: usize },
    /// Per-block choice learned by calibration (see [`calibrate`]).
    Custom { jacobi_mask: Vec<bool> },
}

impl DecodePolicy {
    /// Parse CLI string: "sequential" | "ujd" | "selective" | "selective:N".
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "sequential" | "seq" => Some(DecodePolicy::Sequential),
            "ujd" | "uniform" | "jacobi" => Some(DecodePolicy::UniformJacobi),
            "selective" | "sjd" => Some(DecodePolicy::Selective { seq_blocks: 1 }),
            _ => {
                let n = s.strip_prefix("selective:")?.parse().ok()?;
                Some(DecodePolicy::Selective { seq_blocks: n })
            }
        }
    }

    /// Should decode-position `pos` (0-based, 0 = first block after noise)
    /// use Jacobi?
    pub fn use_jacobi(&self, pos: usize, total_blocks: usize) -> bool {
        debug_assert!(pos < total_blocks);
        match self {
            DecodePolicy::Sequential => false,
            DecodePolicy::UniformJacobi => true,
            DecodePolicy::Selective { seq_blocks } => pos >= *seq_blocks,
            DecodePolicy::Custom { jacobi_mask } => {
                jacobi_mask.get(pos).copied().unwrap_or(true)
            }
        }
    }

    pub fn label(&self) -> String {
        match self {
            DecodePolicy::Sequential => "Sequential".into(),
            DecodePolicy::UniformJacobi => "UJD".into(),
            DecodePolicy::Selective { seq_blocks: 1 } => "SJD".into(),
            DecodePolicy::Selective { seq_blocks } => format!("SJD(seq={seq_blocks})"),
            DecodePolicy::Custom { .. } => "Adaptive".into(),
        }
    }
}

/// Calibration: decide per-block Jacobi vs sequential from measured stats.
///
/// A block prefers Jacobi when its measured Jacobi wall time beats the
/// estimated sequential wall time for the same block. `seq_wall` comes from
/// a sequential calibration pass; if a block's Jacobi decode failed to
/// converge within the cap it is forced sequential.
pub fn calibrate(
    jacobi: &[JacobiStats],
    seq_wall: &[std::time::Duration],
) -> DecodePolicy {
    assert_eq!(jacobi.len(), seq_wall.len());
    let mask = jacobi
        .iter()
        .zip(seq_wall)
        .map(|(j, s)| j.converged && j.wall < *s)
        .collect();
    DecodePolicy::Custom { jacobi_mask: mask }
}

impl DecodePolicy {
    /// Serialize to JSON (calibration persistence: `sjd calibrate` writes
    /// this; `sjd serve --policy @file.json` loads it).
    pub fn to_json(&self) -> crate::jsonx::Value {
        use crate::jsonx::Value;
        match self {
            DecodePolicy::Sequential => Value::obj(vec![("kind", Value::str("sequential"))]),
            DecodePolicy::UniformJacobi => Value::obj(vec![("kind", Value::str("ujd"))]),
            DecodePolicy::Selective { seq_blocks } => Value::obj(vec![
                ("kind", Value::str("selective")),
                ("seq_blocks", Value::num(*seq_blocks as f64)),
            ]),
            DecodePolicy::Custom { jacobi_mask } => Value::obj(vec![
                ("kind", Value::str("custom")),
                (
                    "jacobi_mask",
                    Value::Arr(jacobi_mask.iter().map(|&b| Value::Bool(b)).collect()),
                ),
            ]),
        }
    }

    /// Deserialize from JSON.
    pub fn from_json(v: &crate::jsonx::Value) -> anyhow::Result<Self> {
        use crate::jsonx::Value;
        match v.req_str("kind")? {
            "sequential" => Ok(DecodePolicy::Sequential),
            "ujd" => Ok(DecodePolicy::UniformJacobi),
            "selective" => Ok(DecodePolicy::Selective {
                seq_blocks: v.get("seq_blocks").and_then(Value::as_usize).unwrap_or(1),
            }),
            "custom" => {
                let mask = v
                    .req_arr("jacobi_mask")?
                    .iter()
                    .map(|b| b.as_bool().ok_or_else(|| anyhow::anyhow!("bad mask entry")))
                    .collect::<anyhow::Result<Vec<bool>>>()?;
                Ok(DecodePolicy::Custom { jacobi_mask: mask })
            }
            other => anyhow::bail!("unknown policy kind '{other}'"),
        }
    }

    /// Load from a `@path.json` reference or parse as a CLI string.
    pub fn parse_or_load(s: &str) -> anyhow::Result<Self> {
        if let Some(path) = s.strip_prefix('@') {
            let text = std::fs::read_to_string(path)?;
            return Self::from_json(&crate::jsonx::parse(&text)?);
        }
        Self::parse(s).ok_or_else(|| anyhow::anyhow!("bad policy '{s}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn parse_variants() {
        assert_eq!(DecodePolicy::parse("sequential"), Some(DecodePolicy::Sequential));
        assert_eq!(DecodePolicy::parse("ujd"), Some(DecodePolicy::UniformJacobi));
        assert_eq!(
            DecodePolicy::parse("selective"),
            Some(DecodePolicy::Selective { seq_blocks: 1 })
        );
        assert_eq!(
            DecodePolicy::parse("selective:2"),
            Some(DecodePolicy::Selective { seq_blocks: 2 })
        );
        assert_eq!(DecodePolicy::parse("wat"), None);
    }

    #[test]
    fn selective_matches_paper() {
        // Paper: sequential on the first layer only, Jacobi on the rest.
        let p = DecodePolicy::Selective { seq_blocks: 1 };
        assert!(!p.use_jacobi(0, 4));
        assert!(p.use_jacobi(1, 4));
        assert!(p.use_jacobi(3, 4));
    }

    #[test]
    fn uniform_and_sequential() {
        assert!(DecodePolicy::UniformJacobi.use_jacobi(0, 4));
        assert!(!DecodePolicy::Sequential.use_jacobi(3, 4));
    }

    #[test]
    fn custom_mask() {
        let p = DecodePolicy::Custom { jacobi_mask: vec![false, true, false] };
        assert!(!p.use_jacobi(0, 3));
        assert!(p.use_jacobi(1, 3));
        assert!(!p.use_jacobi(2, 3));
    }

    #[test]
    fn calibrate_prefers_faster_converged() {
        let mk = |block, iters, ms, converged| JacobiStats {
            block,
            iterations: iters,
            wall: Duration::from_millis(ms),
            residuals: vec![],
            converged,
        };
        let jacobi = vec![
            mk(0, 64, 900, true),  // slower than seq → sequential
            mk(1, 5, 50, true),    // faster → jacobi
            mk(2, 64, 10, false),  // failed to converge → sequential
        ];
        let seq = vec![
            Duration::from_millis(500),
            Duration::from_millis(500),
            Duration::from_millis(500),
        ];
        let p = calibrate(&jacobi, &seq);
        assert_eq!(
            p,
            DecodePolicy::Custom { jacobi_mask: vec![false, true, false] }
        );
    }

    #[test]
    fn json_roundtrip_all_variants() {
        for p in [
            DecodePolicy::Sequential,
            DecodePolicy::UniformJacobi,
            DecodePolicy::Selective { seq_blocks: 2 },
            DecodePolicy::Custom { jacobi_mask: vec![false, true, true] },
        ] {
            let j = p.to_json();
            let back = DecodePolicy::from_json(&j).unwrap();
            assert_eq!(p, back);
        }
    }

    #[test]
    fn parse_or_load_file() {
        let p = DecodePolicy::Custom { jacobi_mask: vec![false, true] };
        let path = std::env::temp_dir().join("sjd_policy_test.json");
        std::fs::write(&path, crate::jsonx::to_string_pretty(&p.to_json())).unwrap();
        let loaded =
            DecodePolicy::parse_or_load(&format!("@{}", path.display())).unwrap();
        assert_eq!(loaded, p);
        // Plain strings still parse.
        assert_eq!(
            DecodePolicy::parse_or_load("ujd").unwrap(),
            DecodePolicy::UniformJacobi
        );
        assert!(DecodePolicy::parse_or_load("nope").is_err());
    }

    #[test]
    fn labels() {
        assert_eq!(DecodePolicy::Sequential.label(), "Sequential");
        assert_eq!(DecodePolicy::Selective { seq_blocks: 1 }.label(), "SJD");
        assert_eq!(DecodePolicy::UniformJacobi.label(), "UJD");
    }
}
