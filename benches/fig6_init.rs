//! **Fig 6**: initialization ablation — zeros vs N(0, I) vs previous-layer
//! output as the Jacobi starting point. Paper shape: acceleration is
//! insensitive to initialization (superlinear local convergence dominates).

mod common;

use common::*;
use sjd::benchkit::Report;
use sjd::coordinator::jacobi::InitStrategy;
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::sampler::{SampleOptions, Sampler};
use sjd::tensor::Pcg64;

fn main() -> anyhow::Result<()> {
    let engine = engine_or_skip();
    let model = "tf10";
    let batch = *engine.manifest().model(model)?.batch_sizes.iter().max().unwrap();
    let sampler = Sampler::new(&engine, model, batch)?;
    let reps = if quick() { 2 } else { 8 };

    let mut report = Report::new("Fig 6 — initialization ablation");
    let mut rows = Vec::new();

    for (init, label) in [
        (InitStrategy::Zeros, "zeros"),
        (InitStrategy::Normal, "N(0, I)"),
        (InitStrategy::PrevLayer, "prev layer"),
    ] {
        let mut opts = SampleOptions {
            policy: DecodePolicy::Selective { seq_blocks: 1 },
            ..Default::default()
        };
        opts.jacobi.init = init;
        // Warmup.
        let mut rng = Pcg64::seed(1);
        let _ = sampler.sample_images(&opts, &mut rng)?;
        let mut wall = 0.0;
        let mut iters = 0usize;
        for rep in 0..reps {
            opts.seed = rep as u64;
            let mut rng = Pcg64::seed(100 + rep as u64);
            let (_, out) = sampler.sample_images(&opts, &mut rng)?;
            wall += out.total_wall.as_secs_f64();
            iters += out.total_jacobi_iters();
        }
        let per_batch = wall / reps as f64;
        let mean_iters = iters as f64 / reps as f64;
        println!("{label}: {per_batch:.3}s/batch, {mean_iters:.1} jacobi iters");
        rows.push(vec![label.into(), format!("{per_batch:.3}"), format!("{mean_iters:.1}")]);
    }

    report.table(&["Initialization", "Time/batch (s)", "Mean Jacobi iters"], &rows);
    report.note("Paper shape: all initializations give similar acceleration.");
    report.finish();
    Ok(())
}
