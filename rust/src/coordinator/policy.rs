//! Decode-policy selection (paper §3.5, "Where to Use Jacobi Decoding").
//!
//! The flow has `K` blocks decoded in order `k = K, K−1, …, 1` during
//! sampling (noise → data). Block index here is the *decode position*
//! `0 .. K-1` where position 0 is the first block applied to Gaussian noise —
//! the paper's "first layer" with low redundancy.
//!
//! Every policy reduces to a per-position [`BlockDecode`] via
//! [`DecodePolicy::block_mode`]: sequential KV-cached decoding, full-sequence
//! Jacobi, or windowed GS-Jacobi (see
//! [`gs_jacobi_decode_block_v`](super::jacobi::gs_jacobi_decode_block_v)).
//! Calibration ([`calibrate`], [`calibrate_windows`]) learns a policy from
//! measured per-block decode traces; learned policies serialize to JSON
//! (`sjd calibrate` writes them, `--policy @file` / `--policy-file` load
//! them back).

use super::jacobi::{InitStrategy, JacobiStats};
use super::sampler::{SampleOptions, SampleOutput};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Default window count for the `"gs"` policy shorthand.
pub const DEFAULT_GS_WINDOWS: usize = 4;

/// Default first-chunk size for the `"fuse"` policy shorthand — matches the
/// history length the python side lowers into the fused artifacts
/// (`aot.JSTEP_FUSE_STEPS`), so a default decode runs maximal chunks. The
/// drivers discover the real device cap from the returned history shape;
/// this is only the scheduler seed.
pub const DEFAULT_FUSE_CHUNK: usize = 8;

/// How one decode position is handled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockDecode {
    /// Autoregressive KV-cached decoding (L artifact calls).
    Sequential,
    /// Full-sequence Jacobi iteration (paper Alg 1).
    Jacobi,
    /// Windowed GS-Jacobi: Gauss–Seidel across `windows` windows, Jacobi
    /// inside the active window.
    GsJacobi { windows: usize },
    /// Full-sequence Jacobi through the fused multi-step artifact
    /// (`jacobi_decode_block_fused_v`): chunked dispatch with one residual
    /// history sync per chunk instead of per iteration. `chunk` seeds the
    /// first chunk — a calibrated per-block iteration count makes
    /// single-chunk decodes the common case.
    Fused { chunk: usize },
    /// Windowed GS-Jacobi with the fused multi-step window artifact
    /// (`gs_jacobi_decode_block_fused_v`): GS sweep semantics of
    /// [`BlockDecode::GsJacobi`], inner loops chunked like
    /// [`BlockDecode::Fused`].
    GsFused { windows: usize, chunk: usize },
}

impl BlockDecode {
    /// Short human-readable form for mode tables (`sjd policy show`,
    /// `/policy` endpoint): `sequential`, `jacobi`, `gs W=4`, `fuse S=3`,
    /// `gs_fuse W=8 S=4`.
    pub fn describe(&self) -> String {
        match self {
            BlockDecode::Sequential => "sequential".into(),
            BlockDecode::Jacobi => "jacobi".into(),
            BlockDecode::GsJacobi { windows } => format!("gs W={windows}"),
            BlockDecode::Fused { chunk } => format!("fuse S={chunk}"),
            BlockDecode::GsFused { windows, chunk } => format!("gs_fuse W={windows} S={chunk}"),
        }
    }

    /// Serialize one block mode (the per-mode half of the policy-JSON
    /// format `sjd calibrate` writes and the tuner snapshot reuses).
    pub fn to_json(self) -> crate::jsonx::Value {
        use crate::jsonx::Value;
        match self {
            BlockDecode::Sequential => Value::obj(vec![("mode", Value::str("sequential"))]),
            BlockDecode::Jacobi => Value::obj(vec![("mode", Value::str("jacobi"))]),
            BlockDecode::GsJacobi { windows } => Value::obj(vec![
                ("mode", Value::str("gs")),
                ("windows", Value::num(windows as f64)),
            ]),
            BlockDecode::Fused { chunk } => Value::obj(vec![
                ("mode", Value::str("fuse")),
                ("chunk", Value::num(chunk as f64)),
            ]),
            BlockDecode::GsFused { windows, chunk } => Value::obj(vec![
                ("mode", Value::str("gs_fuse")),
                ("windows", Value::num(windows as f64)),
                ("chunk", Value::num(chunk as f64)),
            ]),
        }
    }

    /// Inverse of [`BlockDecode::to_json`].
    pub fn from_json(v: &crate::jsonx::Value) -> anyhow::Result<Self> {
        match v.req_str("mode")? {
            "sequential" => Ok(BlockDecode::Sequential),
            "jacobi" => Ok(BlockDecode::Jacobi),
            "gs" => Ok(BlockDecode::GsJacobi { windows: windows_from_json(v)? }),
            "fuse" => Ok(BlockDecode::Fused { chunk: chunk_from_json(v)? }),
            "gs_fuse" => Ok(BlockDecode::GsFused {
                windows: windows_from_json(v)?,
                chunk: chunk_from_json(v)?,
            }),
            other => anyhow::bail!("unknown block mode '{other}'"),
        }
    }
}

/// Read an optional `windows` field: absent ⇒ the default, present ⇒ must be
/// a positive integer (a malformed value is an error, never silently the
/// default — the operator's policy file means what it says).
fn windows_from_json(v: &crate::jsonx::Value) -> anyhow::Result<usize> {
    match v.get("windows") {
        None => Ok(DEFAULT_GS_WINDOWS),
        Some(w) => w
            .as_usize()
            .filter(|&w| w >= 1)
            .ok_or_else(|| anyhow::anyhow!("gs windows must be a positive integer, got {w:?}")),
    }
}

/// Read an optional `chunk` field with the same strictness as
/// [`windows_from_json`]: absent ⇒ the default, present-but-malformed ⇒ an
/// error, never silently the default.
fn chunk_from_json(v: &crate::jsonx::Value) -> anyhow::Result<usize> {
    match v.get("chunk") {
        None => Ok(DEFAULT_FUSE_CHUNK),
        Some(c) => c
            .as_usize()
            .filter(|&c| c >= 1)
            .ok_or_else(|| anyhow::anyhow!("fuse chunk must be a positive integer, got {c:?}")),
    }
}

/// How each of the `K` blocks is decoded.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DecodePolicy {
    /// Standard sequential (autoregressive, KV cache) everywhere — the
    /// paper's baseline.
    Sequential,
    /// Jacobi everywhere (paper's "UJD" baseline).
    UniformJacobi,
    /// Paper's SJD: sequential for the first `seq_blocks` decode positions,
    /// Jacobi for the rest. `seq_blocks = 1` is the paper's setting.
    Selective { seq_blocks: usize },
    /// Windowed GS-Jacobi at every decode position. `windows = 1` is
    /// equivalent to [`DecodePolicy::UniformJacobi`]; `windows = L` is
    /// sequential-equivalent work done through the jstep_win artifact.
    GsJacobi { windows: usize },
    /// Fused chunked Jacobi at every decode position
    /// ([`BlockDecode::Fused`]) — UJD semantics with `⌈t/S⌉` host syncs per
    /// block instead of `t`. The sampler falls back to plain Jacobi where
    /// the fused artifact is absent.
    Fused { chunk: usize },
    /// Per-block Jacobi-vs-sequential choice learned by [`calibrate`].
    Custom { jacobi_mask: Vec<bool> },
    /// Fully per-block decode modes (window counts included) learned by
    /// [`calibrate_windows`].
    PerBlock { modes: Vec<BlockDecode> },
}

impl DecodePolicy {
    /// Parse CLI string:
    /// `"sequential" | "ujd" | "selective[:N]" | "gs[:W]" | "fuse[:S]"`.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "sequential" | "seq" => Some(DecodePolicy::Sequential),
            "ujd" | "uniform" | "jacobi" => Some(DecodePolicy::UniformJacobi),
            "selective" | "sjd" => Some(DecodePolicy::Selective { seq_blocks: 1 }),
            "gs" | "gs-jacobi" => Some(DecodePolicy::GsJacobi { windows: DEFAULT_GS_WINDOWS }),
            "fuse" | "fused" => Some(DecodePolicy::Fused { chunk: DEFAULT_FUSE_CHUNK }),
            _ => {
                if let Some(n) = s.strip_prefix("selective:") {
                    return Some(DecodePolicy::Selective { seq_blocks: n.parse().ok()? });
                }
                if let Some(c) = s.strip_prefix("fuse:") {
                    let chunk: usize = c.parse().ok()?;
                    if chunk == 0 {
                        return None;
                    }
                    return Some(DecodePolicy::Fused { chunk });
                }
                let w: usize = s.strip_prefix("gs:")?.parse().ok()?;
                if w == 0 {
                    return None;
                }
                Some(DecodePolicy::GsJacobi { windows: w })
            }
        }
    }

    /// Decode mode for decode-position `pos` (0-based, 0 = first block after
    /// noise).
    pub fn block_mode(&self, pos: usize, total_blocks: usize) -> BlockDecode {
        debug_assert!(pos < total_blocks);
        match self {
            DecodePolicy::Sequential => BlockDecode::Sequential,
            DecodePolicy::UniformJacobi => BlockDecode::Jacobi,
            DecodePolicy::Selective { seq_blocks } => {
                if pos < *seq_blocks {
                    BlockDecode::Sequential
                } else {
                    BlockDecode::Jacobi
                }
            }
            DecodePolicy::GsJacobi { windows } => BlockDecode::GsJacobi { windows: *windows },
            DecodePolicy::Fused { chunk } => BlockDecode::Fused { chunk: *chunk },
            DecodePolicy::Custom { jacobi_mask } => {
                if jacobi_mask.get(pos).copied().unwrap_or(true) {
                    BlockDecode::Jacobi
                } else {
                    BlockDecode::Sequential
                }
            }
            DecodePolicy::PerBlock { modes } => {
                modes.get(pos).copied().unwrap_or(BlockDecode::Jacobi)
            }
        }
    }

    /// Should decode-position `pos` use a Jacobi-family decode? (Legacy
    /// predicate over [`DecodePolicy::block_mode`].)
    pub fn use_jacobi(&self, pos: usize, total_blocks: usize) -> bool {
        self.block_mode(pos, total_blocks) != BlockDecode::Sequential
    }

    pub fn label(&self) -> String {
        match self {
            DecodePolicy::Sequential => "Sequential".into(),
            DecodePolicy::UniformJacobi => "UJD".into(),
            DecodePolicy::Selective { seq_blocks: 1 } => "SJD".into(),
            DecodePolicy::Selective { seq_blocks } => format!("SJD(seq={seq_blocks})"),
            DecodePolicy::GsJacobi { windows } => format!("GS-Jacobi(W={windows})"),
            DecodePolicy::Fused { chunk } => format!("Fused(S={chunk})"),
            DecodePolicy::Custom { .. } => "Adaptive".into(),
            DecodePolicy::PerBlock { .. } => "Adaptive-GS".into(),
        }
    }
}

/// Calibration: decide per-block Jacobi vs sequential from measured stats.
///
/// A block prefers Jacobi when its measured Jacobi wall time beats the
/// estimated sequential wall time for the same block. `seq_wall` comes from
/// a sequential calibration pass; if a block's Jacobi decode failed to
/// converge within the cap it is forced sequential.
pub fn calibrate(
    jacobi: &[JacobiStats],
    seq_wall: &[std::time::Duration],
) -> DecodePolicy {
    assert_eq!(jacobi.len(), seq_wall.len());
    let mask = jacobi
        .iter()
        .zip(seq_wall)
        .map(|(j, s)| j.converged && j.wall < *s)
        .collect();
    DecodePolicy::Custom { jacobi_mask: mask }
}

/// The shared window/chunk law: the [`BlockDecode`] a block whose
/// full-sequence Jacobi decode converges in `iters` iterations should use.
///
/// One formula serves both the offline calibrators below and the online
/// [`PolicyTuner`], so "converges to the calibrated answer" is a statement
/// about iteration *estimates*, never about two drifting heuristics.
///
/// The window-count half follows the GS-Jacobi cost model: a window of
/// length `len` converges in ≈ `min(t, len)` iterations, where `t` is the
/// block's measured full-sequence iteration count. A *hard* block
/// (`t ≈ L`, sequential-like coupling) costs `L²` position-updates under
/// plain Jacobi but `≈ L²/W` under `W` windows — more windows strictly help.
/// An *easy* block (`t ≪ L/W`) costs `t·L` either way, so extra windows only
/// add per-call overhead — one window (plain Jacobi) is best. Interpolating,
/// the learned count is `round(t/L · max_windows)`, clamped to
/// `[1, max_windows]`.
///
/// With `fused_s_max = Some(S)` the mode routes through the fused multi-step
/// artifacts and the chunk half applies: the first-chunk seed is the
/// measured iteration count (`t` full-sequence, `⌈t/W⌉` per window), clamped
/// to the lowered history length `S` — a calibrated block then decodes in a
/// single chunk, one host sync.
pub fn mode_for_iters(
    iters: usize,
    seq_len: usize,
    max_windows: usize,
    fused_s_max: Option<usize>,
) -> BlockDecode {
    assert!(seq_len > 0 && max_windows > 0);
    let iters = iters.max(1);
    let ratio = iters as f64 / seq_len as f64;
    let windows = ((ratio * max_windows as f64).round() as usize).clamp(1, max_windows);
    match (windows, fused_s_max) {
        (1, None) => BlockDecode::Jacobi,
        (1, Some(s)) => BlockDecode::Fused { chunk: iters.clamp(1, s) },
        (w, None) => BlockDecode::GsJacobi { windows: w },
        (w, Some(s)) => {
            BlockDecode::GsFused { windows: w, chunk: iters.div_ceil(w).clamp(1, s) }
        }
    }
}

/// Window-aware calibration: learn a per-block [`BlockDecode`] — including
/// GS-Jacobi window counts — from full-sequence Jacobi iteration traces,
/// through the shared [`mode_for_iters`] law.
///
/// Blocks whose Jacobi decode failed to converge within the cap, or measured
/// slower than their sequential pass, stay sequential (the conservative
/// choice [`calibrate`] makes too).
pub fn calibrate_windows(
    jacobi: &[JacobiStats],
    seq_wall: &[std::time::Duration],
    seq_len: usize,
    max_windows: usize,
) -> DecodePolicy {
    assert_eq!(jacobi.len(), seq_wall.len());
    let modes = jacobi
        .iter()
        .zip(seq_wall)
        .map(|(j, s)| {
            if !j.converged || j.wall >= *s {
                BlockDecode::Sequential
            } else {
                mode_for_iters(j.iterations, seq_len, max_windows, None)
            }
        })
        .collect();
    DecodePolicy::PerBlock { modes }
}

/// Chunk-aware calibration (`sjd calibrate --chunks`): the per-block modes
/// of [`calibrate_windows`], routed through the **fused multi-step**
/// artifacts with per-block chunk schedules learned from the same iteration
/// traces — [`mode_for_iters`] with the fused history cap supplied.
///
/// The first-chunk seed is the point of calibration: a block measured to
/// converge in `t` iterations gets `chunk = t` (full-sequence fused decode
/// lands its very first chunk exactly on the τ crossing — one host sync,
/// bit-identical iterate) and a windowed block gets `⌈t/W⌉` (the expected
/// per-window share of the trace). Both are clamped to `s_max`, the fused
/// artifacts' lowered history length, because a chunk can never run past
/// the device-side history. Blocks that failed to converge or measured
/// slower than sequential stay sequential, exactly like
/// [`calibrate_windows`].
pub fn calibrate_chunks(
    jacobi: &[JacobiStats],
    seq_wall: &[std::time::Duration],
    seq_len: usize,
    max_windows: usize,
    s_max: usize,
) -> DecodePolicy {
    assert!(s_max > 0);
    assert_eq!(jacobi.len(), seq_wall.len());
    let modes = jacobi
        .iter()
        .zip(seq_wall)
        .map(|(j, s)| {
            if !j.converged || j.wall >= *s {
                BlockDecode::Sequential
            } else {
                mode_for_iters(j.iterations, seq_len, max_windows, Some(s_max))
            }
        })
        .collect();
    DecodePolicy::PerBlock { modes }
}

impl DecodePolicy {
    /// Serialize to JSON (calibration persistence: `sjd calibrate` writes
    /// this; `sjd serve --policy @file.json` loads it).
    pub fn to_json(&self) -> crate::jsonx::Value {
        use crate::jsonx::Value;
        match self {
            DecodePolicy::Sequential => Value::obj(vec![("kind", Value::str("sequential"))]),
            DecodePolicy::UniformJacobi => Value::obj(vec![("kind", Value::str("ujd"))]),
            DecodePolicy::Selective { seq_blocks } => Value::obj(vec![
                ("kind", Value::str("selective")),
                ("seq_blocks", Value::num(*seq_blocks as f64)),
            ]),
            DecodePolicy::GsJacobi { windows } => Value::obj(vec![
                ("kind", Value::str("gs")),
                ("windows", Value::num(*windows as f64)),
            ]),
            DecodePolicy::Fused { chunk } => Value::obj(vec![
                ("kind", Value::str("fuse")),
                ("chunk", Value::num(*chunk as f64)),
            ]),
            DecodePolicy::Custom { jacobi_mask } => Value::obj(vec![
                ("kind", Value::str("custom")),
                (
                    "jacobi_mask",
                    Value::Arr(jacobi_mask.iter().map(|&b| Value::Bool(b)).collect()),
                ),
            ]),
            DecodePolicy::PerBlock { modes } => Value::obj(vec![
                ("kind", Value::str("per_block")),
                ("modes", Value::Arr(modes.iter().map(|m| m.to_json()).collect())),
            ]),
        }
    }

    /// Deserialize from JSON.
    pub fn from_json(v: &crate::jsonx::Value) -> anyhow::Result<Self> {
        use crate::jsonx::Value;
        match v.req_str("kind")? {
            "sequential" => Ok(DecodePolicy::Sequential),
            "ujd" => Ok(DecodePolicy::UniformJacobi),
            "selective" => Ok(DecodePolicy::Selective {
                seq_blocks: v.get("seq_blocks").and_then(Value::as_usize).unwrap_or(1),
            }),
            "gs" => Ok(DecodePolicy::GsJacobi { windows: windows_from_json(v)? }),
            "fuse" => Ok(DecodePolicy::Fused { chunk: chunk_from_json(v)? }),
            "custom" => {
                let mask = v
                    .req_arr("jacobi_mask")?
                    .iter()
                    .map(|b| b.as_bool().ok_or_else(|| anyhow::anyhow!("bad mask entry")))
                    .collect::<anyhow::Result<Vec<bool>>>()?;
                Ok(DecodePolicy::Custom { jacobi_mask: mask })
            }
            "per_block" => {
                let modes = v
                    .req_arr("modes")?
                    .iter()
                    .map(BlockDecode::from_json)
                    .collect::<anyhow::Result<Vec<_>>>()?;
                Ok(DecodePolicy::PerBlock { modes })
            }
            other => anyhow::bail!("unknown policy kind '{other}'"),
        }
    }

    /// Load from a `@path.json` reference or parse as a CLI string.
    pub fn parse_or_load(s: &str) -> anyhow::Result<Self> {
        if let Some(path) = s.strip_prefix('@') {
            let text = std::fs::read_to_string(path)?;
            return Self::from_json(&crate::jsonx::parse(&text)?);
        }
        Self::parse(s).ok_or_else(|| anyhow::anyhow!("bad policy '{s}'"))
    }
}

// ---------------------------------------------------------------------------
// Init policy (speculative z⁰ providers)
// ---------------------------------------------------------------------------

/// Default warm-start cache capacity for the `warm[:N]` spelling — mirrors
/// the `BufferPool` default so a bare `--init warm` and an unconfigured pool
/// agree on the bound.
pub const DEFAULT_WARM_CAP: usize = 32;

/// How Jacobi iterates are seeded (`--init`): a parsed [`InitStrategy`] plus
/// the provider knobs that ride along in policy JSON. Round-trips through
/// [`InitPolicy::parse`]/[`InitPolicy::label`] and `to_json`/`from_json`
/// with the same strictness as the decode-policy spellings: absent fields
/// default, present-but-malformed fields are errors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct InitPolicy {
    pub strategy: InitStrategy,
    /// Warm-start cache capacity in entries — the `N` of `warm:N`.
    pub warm_cap: usize,
}

impl Default for InitPolicy {
    fn default() -> Self {
        InitPolicy { strategy: InitStrategy::Zeros, warm_cap: DEFAULT_WARM_CAP }
    }
}

impl InitPolicy {
    /// Parse CLI string:
    /// `"zeros" | "normal" | "prev" | "proj" | "draft" | "warm[:N]"` —
    /// every [`InitStrategy`] spelling, plus the capacity argument on the
    /// warm-start provider.
    pub fn parse(s: &str) -> Option<Self> {
        if let Some(n) = s.strip_prefix("warm:") {
            let cap: usize = n.parse().ok()?;
            if cap == 0 {
                return None;
            }
            return Some(InitPolicy { strategy: InitStrategy::Warm, warm_cap: cap });
        }
        Some(InitPolicy { strategy: InitStrategy::parse(s)?, ..Default::default() })
    }

    /// Canonical spelling — parses back to itself.
    pub fn label(&self) -> String {
        match self.strategy {
            InitStrategy::Warm if self.warm_cap != DEFAULT_WARM_CAP => {
                format!("warm:{}", self.warm_cap)
            }
            s => s.label().to_string(),
        }
    }

    /// Serialize (the `"init"` half of a policy file `sjd calibrate` writes).
    pub fn to_json(&self) -> crate::jsonx::Value {
        use crate::jsonx::Value;
        let mut fields = vec![("strategy", Value::str(self.strategy.label()))];
        if self.strategy == InitStrategy::Warm {
            fields.push(("warm_cap", Value::num(self.warm_cap as f64)));
        }
        Value::obj(fields)
    }

    /// Inverse of [`InitPolicy::to_json`]: an unknown strategy or a
    /// malformed `warm_cap` is an error, never silently the default.
    pub fn from_json(v: &crate::jsonx::Value) -> anyhow::Result<Self> {
        let s = v.req_str("strategy")?;
        let strategy = InitStrategy::parse(s)
            .ok_or_else(|| anyhow::anyhow!("unknown init strategy '{s}'"))?;
        let warm_cap = match v.get("warm_cap") {
            None => DEFAULT_WARM_CAP,
            Some(c) => c.as_usize().filter(|&c| c >= 1).ok_or_else(|| {
                anyhow::anyhow!("init warm_cap must be a positive integer, got {c:?}")
            })?,
        };
        Ok(InitPolicy { strategy, warm_cap })
    }
}

// ---------------------------------------------------------------------------
// Online policy autotuner
// ---------------------------------------------------------------------------

/// Knobs of the online [`PolicyTuner`].
#[derive(Clone, Debug)]
pub struct TunerConfig {
    /// EWMA weight of the newest iteration observation (0 < α ≤ 1).
    pub alpha: f64,
    /// Window-count ceiling, like `sjd calibrate --windows`.
    pub max_windows: usize,
    /// Fused-artifact history length `S` — caps learned chunk sizes and
    /// sizes the full-sequence probe mode.
    pub s_max: usize,
    /// Full-sequence observations required per (bucket, block) before the
    /// tuner leaves the bootstrap policy for that block.
    pub min_obs: usize,
    /// Probe cadence: every `probe_every`-th decode of a tuned block runs in
    /// the full-sequence measuring mode to refresh its estimate (0 disables
    /// re-probing; blocks tuned into full-sequence modes measure for free on
    /// every decode regardless).
    pub probe_every: usize,
    /// Hysteresis dwell: a newly derived mode must recur on this many
    /// consecutive measurements before it replaces the applied mode, so
    /// boundary-straddling iteration estimates cannot flap the policy.
    pub dwell: usize,
}

impl Default for TunerConfig {
    fn default() -> Self {
        TunerConfig {
            alpha: 0.25,
            max_windows: 8,
            s_max: DEFAULT_FUSE_CHUNK,
            min_obs: 3,
            probe_every: 16,
            dwell: 3,
        }
    }
}

/// Per-(bucket, position) speculative-init evidence: EWMAs of the total
/// position-updates one decode of this block costs under the Zeros baseline
/// vs. under the requested provider (refine updates **plus** the provider's
/// own speculation cost, so a draft pass that merely moves work around
/// cannot look like savings).
#[derive(Clone, Debug, Default)]
struct SpecCell {
    /// EWMA of `position_updates` on Zeros-init decodes.
    base: Option<f64>,
    /// EWMA of `position_updates + spec_cost_updates` on provider decodes.
    spec: Option<f64>,
}

/// Per-bucket speculative-init state.
#[derive(Clone, Debug, Default)]
struct SpecBucket {
    cells: Vec<SpecCell>,
    /// Decodes observed under the Zeros baseline / the provider.
    base_obs: usize,
    spec_obs: usize,
    /// Decodes routed through [`PolicyTuner::init_for`] (probe clock).
    decodes: usize,
    /// Realized savings went negative: the bucket runs Zeros, re-probing the
    /// provider on the probe cadence so a regime change can win it back.
    reverted: bool,
}

/// Per-(bucket, block) tuner state.
#[derive(Clone, Debug, Default)]
struct TunerCell {
    /// EWMA of measured full-sequence Jacobi iteration counts.
    ewma_iters: Option<f64>,
    /// Full-sequence observations folded into the EWMA.
    obs: usize,
    /// Decodes routed through this cell (probe-cadence clock).
    decodes: usize,
    /// Currently applied mode; `None` while still bootstrapping.
    mode: Option<BlockDecode>,
    /// Hysteresis state: a candidate mode and how many consecutive
    /// measurements have derived it.
    candidate: Option<(BlockDecode, usize)>,
}

/// Online policy autotuner (`sjd serve --tune`): closes the calibration loop
/// from live traffic instead of an offline `sjd calibrate` run.
///
/// Every decode already produces per-block iteration/residual/host-sync
/// stats ([`SampleOutput`] traces); the tuner folds them into EWMA iteration
/// estimates per **(bucket, block)** — convergence behavior genuinely varies
/// with the batch size, so buckets tune independently — and derives each
/// block's mode through the same [`mode_for_iters`] law the offline
/// calibrators use. Mode changes apply under hysteresis
/// ([`TunerConfig::dwell`]) so noisy boundary estimates cannot flap the
/// policy, and the derived modes stay inside the documented degradation
/// chain (`gs_fuse → gs → jacobi`, `fuse → jacobi`): the tuner always emits
/// the fused variants and the `Sampler` degrades them wherever the artifacts
/// are missing.
///
/// **Measurement.** Only *full-sequence* Jacobi-family traces measure a
/// block's dependency redundancy `t` (windowed GS iterations are per-window
/// quantities). Blocks tuned into full-sequence modes (`jacobi`/`fuse`)
/// therefore measure for free on every decode; blocks tuned into windowed
/// modes are re-measured by routing every [`TunerConfig::probe_every`]-th
/// decode through the full-sequence probe mode (`fuse` with a maximal
/// chunk — `⌈t/S⌉` host syncs, the cheapest exact measurement available).
/// A probe that fails to converge within the Prop 3.2 cap derives
/// `Sequential`, mirroring the offline calibrators' conservative choice.
///
/// Blocks the bootstrap policy pins `Sequential` (e.g. the paper's
/// dependency-heavy first decode position under the default `selective`) are
/// never tuned — SeJD's "where to use Jacobi" law stays an operator
/// decision; the tuner optimizes *how* the Jacobi-family blocks decode.
///
/// Shared across router workers behind an `Arc`; all state sits behind one
/// mutex (two short critical sections per decoded batch).
#[derive(Debug)]
pub struct PolicyTuner {
    cfg: TunerConfig,
    blocks: usize,
    seq_len: usize,
    bootstrap: DecodePolicy,
    cells: Mutex<BTreeMap<usize, Vec<TunerCell>>>,
    /// Operator-requested init provider (`--init`); tuner-gated per bucket
    /// when speculative.
    init: InitStrategy,
    spec: Mutex<BTreeMap<usize, SpecBucket>>,
}

impl PolicyTuner {
    pub fn new(blocks: usize, seq_len: usize, bootstrap: DecodePolicy, cfg: TunerConfig) -> Self {
        assert!(blocks > 0 && seq_len > 0);
        assert!(cfg.alpha > 0.0 && cfg.alpha <= 1.0);
        assert!(cfg.max_windows > 0 && cfg.s_max > 0 && cfg.min_obs > 0 && cfg.dwell > 0);
        PolicyTuner {
            cfg,
            blocks,
            seq_len,
            bootstrap,
            cells: Mutex::new(BTreeMap::new()),
            init: InitStrategy::Zeros,
            spec: Mutex::new(BTreeMap::new()),
        }
    }

    /// Set the operator-requested init provider (`serve --tune --init …`).
    /// Non-speculative strategies pass through [`PolicyTuner::init_for`]
    /// unchanged; speculative providers become tuner-gated — applied only
    /// while their realized position-update savings stay non-negative.
    pub fn with_init(mut self, init: InitStrategy) -> Self {
        self.init = init;
        self
    }

    /// The init strategy the next decode of `bucket` should run — the router
    /// calls this beside [`PolicyTuner::policy_for`]. Advances the per-bucket
    /// probe clock: while the baseline estimate is still forming the bucket
    /// alternates provider/Zeros decodes, an established provider yields a
    /// Zeros baseline probe every [`TunerConfig::probe_every`]-th decode, and
    /// a reverted bucket re-probes the provider on the same cadence.
    pub fn init_for(&self, bucket: usize) -> InitStrategy {
        if !self.init.is_speculative() {
            return self.init;
        }
        let mut map = self.spec.lock().unwrap();
        let sb = map.entry(bucket).or_default();
        sb.decodes += 1;
        if sb.base_obs < self.cfg.min_obs && sb.decodes % 2 == 0 {
            return InitStrategy::Zeros;
        }
        let probe = self.cfg.probe_every > 0 && sb.decodes % self.cfg.probe_every == 0;
        match (sb.reverted, probe) {
            (false, false) => self.init,
            (false, true) => InitStrategy::Zeros,
            (true, false) => InitStrategy::Zeros,
            (true, true) => self.init,
        }
    }

    /// The full-sequence measuring mode: fused chunked UJD sized to the
    /// device history, degrading to plain per-iteration Jacobi where the
    /// fused artifact is absent — either way the trace reports the exact
    /// τ-stopped iteration count the calibration law needs.
    fn probe_mode(&self) -> BlockDecode {
        BlockDecode::Fused { chunk: self.cfg.s_max }
    }

    fn bootstrap_mode(&self, pos: usize) -> BlockDecode {
        self.bootstrap.block_mode(pos, self.blocks)
    }

    fn fresh_cells(&self) -> Vec<TunerCell> {
        vec![TunerCell::default(); self.blocks]
    }

    /// The policy the next decode of `bucket` should run — the router calls
    /// this before every batch. Advances the probe clock: bootstrapping or
    /// probe-due blocks come back in the measuring mode.
    pub fn policy_for(&self, bucket: usize) -> DecodePolicy {
        let mut map = self.cells.lock().unwrap();
        let cells = map.entry(bucket).or_insert_with(|| self.fresh_cells());
        let modes = (0..self.blocks)
            .map(|pos| {
                if self.bootstrap_mode(pos) == BlockDecode::Sequential {
                    return BlockDecode::Sequential;
                }
                let cell = &mut cells[pos];
                cell.decodes += 1;
                let probe_due = cell.obs < self.cfg.min_obs
                    || (self.cfg.probe_every > 0 && cell.decodes % self.cfg.probe_every == 0);
                match (cell.mode, probe_due) {
                    (Some(mode), false) => mode,
                    _ => self.probe_mode(),
                }
            })
            .collect();
        DecodePolicy::PerBlock { modes }
    }

    /// Fold one decode's traces into the estimates — the router calls this
    /// with every [`SampleOutput`]. Only full-sequence Jacobi-family traces
    /// carry usable measurements (see the type docs); everything else is
    /// skipped, so feeding every decode unconditionally is correct.
    ///
    /// Returns the **wasted speculative updates** this decode contributed —
    /// position-updates spent above the bucket's Zeros baseline estimate on
    /// provider-initialized blocks (0 whenever the provider paid, or no init
    /// provider is active). The router exports the running sum as the
    /// `sjd_spec_wasted_updates` counter.
    pub fn observe(&self, bucket: usize, out: &SampleOutput) -> usize {
        {
            let mut map = self.cells.lock().unwrap();
            let cells = map.entry(bucket).or_insert_with(|| self.fresh_cells());
            self.observe_modes(cells, out);
        }
        self.observe_init(bucket, out)
    }

    fn observe_modes(&self, cells: &mut [TunerCell], out: &SampleOutput) {
        for trace in &out.traces {
            let pos = trace.position;
            if pos >= cells.len() || self.bootstrap_mode(pos) == BlockDecode::Sequential {
                continue;
            }
            // Full-sequence measurement: plain or fused Jacobi (GS traces
            // report per-window iterations, not the block's t).
            let Some(stats) = &trace.jacobi else { continue };
            let cell = &mut cells[pos];
            let t = stats.iterations.max(1) as f64;
            let ewma = match cell.ewma_iters {
                None => t,
                Some(prev) => self.cfg.alpha * t + (1.0 - self.cfg.alpha) * prev,
            };
            cell.ewma_iters = Some(ewma);
            cell.obs += 1;
            if cell.obs < self.cfg.min_obs {
                continue;
            }
            let derived = if stats.converged {
                mode_for_iters(
                    ewma.round() as usize,
                    self.seq_len,
                    self.cfg.max_windows,
                    Some(self.cfg.s_max),
                )
            } else {
                BlockDecode::Sequential
            };
            match cell.mode {
                // First derivation leaves the bootstrap directly.
                None => cell.mode = Some(derived),
                Some(applied) if applied == derived => cell.candidate = None,
                Some(_) => {
                    let count = match cell.candidate.take() {
                        Some((m, c)) if m == derived => c + 1,
                        _ => 1,
                    };
                    if count >= self.cfg.dwell {
                        cell.mode = Some(derived);
                    } else {
                        cell.candidate = Some((derived, count));
                    }
                }
            }
        }
    }

    /// The speculative-payoff half of [`PolicyTuner::observe`]: fold Zeros
    /// decodes into the baseline EWMAs, provider decodes (refine cost + the
    /// provider's own speculation cost) into the provider EWMAs, and gate —
    /// once both sides carry [`TunerConfig::min_obs`] decodes, the bucket
    /// reverts to Zeros exactly while the summed provider estimate exceeds
    /// the summed baseline (realized savings negative).
    fn observe_init(&self, bucket: usize, out: &SampleOutput) -> usize {
        if !self.init.is_speculative() {
            return 0;
        }
        let fold = |prev: Option<f64>, x: f64| match prev {
            None => x,
            Some(p) => self.cfg.alpha * x + (1.0 - self.cfg.alpha) * p,
        };
        let mut map = self.spec.lock().unwrap();
        let sb = map.entry(bucket).or_default();
        if sb.cells.len() < self.blocks {
            sb.cells.resize(self.blocks, SpecCell::default());
        }
        let (mut saw_base, mut saw_spec) = (false, false);
        let mut wasted = 0.0_f64;
        for trace in &out.traces {
            let Some(cell) = sb.cells.get_mut(trace.position) else { continue };
            if trace.init == self.init {
                let total = (trace.position_updates + trace.spec_cost_updates) as f64;
                if let Some(base) = cell.base {
                    wasted += (total - base).max(0.0);
                }
                cell.spec = Some(fold(cell.spec, total));
                saw_spec = true;
            } else if trace.init == InitStrategy::Zeros {
                cell.base = Some(fold(cell.base, trace.position_updates as f64));
                saw_base = true;
            }
        }
        sb.base_obs += saw_base as usize;
        sb.spec_obs += saw_spec as usize;
        if sb.base_obs >= self.cfg.min_obs && sb.spec_obs >= self.cfg.min_obs {
            let (mut base, mut spec, mut have) = (0.0, 0.0, false);
            for c in &sb.cells {
                if let (Some(b), Some(s)) = (c.base, c.spec) {
                    base += b;
                    spec += s;
                    have = true;
                }
            }
            if have {
                sb.reverted = spec > base;
            }
        }
        wasted.round() as usize
    }

    /// The effective per-block policy for one bucket (applied modes, with
    /// still-bootstrapping blocks at their bootstrap mode); `None` if the
    /// bucket has never decoded.
    pub fn snapshot(&self, bucket: usize) -> Option<DecodePolicy> {
        let map = self.cells.lock().unwrap();
        let cells = map.get(&bucket)?;
        let modes = (0..self.blocks)
            .map(|pos| cells[pos].mode.unwrap_or_else(|| self.bootstrap_mode(pos)))
            .collect();
        Some(DecodePolicy::PerBlock { modes })
    }

    /// The most-observed bucket and its snapshot — what `serve --tune`
    /// persists to the policy-JSON format on shutdown.
    pub fn snapshot_best(&self) -> Option<(usize, DecodePolicy)> {
        let bucket = {
            let map = self.cells.lock().unwrap();
            map.iter()
                .max_by_key(|(_, cells)| cells.iter().map(|c| c.obs).sum::<usize>())
                .map(|(&b, _)| b)?
        };
        Some((bucket, self.snapshot(bucket)?))
    }

    /// Full live state as JSON — the `/policy` endpoint body.
    pub fn to_json(&self) -> crate::jsonx::Value {
        use crate::jsonx::Value;
        let map = self.cells.lock().unwrap();
        let buckets: BTreeMap<String, Value> = map
            .iter()
            .map(|(bucket, cells)| {
                let rows = cells
                    .iter()
                    .enumerate()
                    .map(|(pos, c)| {
                        let mode = c.mode.unwrap_or_else(|| self.bootstrap_mode(pos));
                        Value::obj(vec![
                            ("position", Value::num(pos as f64)),
                            ("block", Value::num((self.blocks - 1 - pos) as f64)),
                            ("mode", mode.to_json()),
                            ("tuned", Value::Bool(c.mode.is_some())),
                            ("ewma_iters", c.ewma_iters.map_or(Value::Null, Value::num)),
                            ("observations", Value::num(c.obs as f64)),
                            ("decodes", Value::num(c.decodes as f64)),
                        ])
                    })
                    .collect();
                (bucket.to_string(), Value::Arr(rows))
            })
            .collect();
        let spec = self.spec.lock().unwrap();
        let init_buckets: BTreeMap<String, Value> = spec
            .iter()
            .map(|(bucket, sb)| {
                (
                    bucket.to_string(),
                    Value::obj(vec![
                        ("active", Value::Bool(!sb.reverted)),
                        ("base_obs", Value::num(sb.base_obs as f64)),
                        ("spec_obs", Value::num(sb.spec_obs as f64)),
                        ("decodes", Value::num(sb.decodes as f64)),
                    ]),
                )
            })
            .collect();
        Value::obj(vec![
            ("source", Value::str("tuner")),
            ("blocks", Value::num(self.blocks as f64)),
            ("seq_len", Value::num(self.seq_len as f64)),
            ("bootstrap", self.bootstrap.to_json()),
            ("buckets", Value::Obj(buckets)),
            (
                "init",
                Value::obj(vec![
                    ("requested", Value::str(self.init.label())),
                    ("buckets", Value::Obj(init_buckets)),
                ]),
            ),
        ])
    }
}

// ---------------------------------------------------------------------------
// Quality-elastic overload governor
// ---------------------------------------------------------------------------

/// Number of τ steps on the degradation ladder above the mode-coarsening
/// levels — the ladder interpolates from `base_tau` to `fidelity_budget`
/// in this many increments.
const GOVERNOR_TAU_STEPS: usize = 3;

/// Configuration for the [`OverloadGovernor`] degradation ladder and its
/// pressure detector. A threshold of `0` disables that signal.
#[derive(Clone, Copy, Debug)]
pub struct GovernorConfig {
    /// EWMA smoothing factor for both pressure signals (tuner-style).
    pub alpha: f64,
    /// Queue-depth EWMA above which the batcher counts as overloaded
    /// (0 = signal disabled). Pressure clears below `queue_high / 2` —
    /// the hysteresis band that prevents threshold flapping.
    pub queue_high: f64,
    /// Accepted-request latency EWMA (milliseconds) above which decode
    /// counts as overloaded (0 = signal disabled); clears below half.
    pub latency_high_ms: f64,
    /// Consecutive over- (under-) pressure observations required before the
    /// ladder steps up (down) one level — the PolicyTuner dwell idiom.
    pub dwell: usize,
    /// The configured τ the service runs at when healthy; the governor
    /// steps back to exactly this value when pressure clears, so the τ=0
    /// bit-exactness contract survives any number of overload episodes.
    pub base_tau: f32,
    /// Upper bound on elastic τ (`--fidelity-budget`). Must exceed
    /// `base_tau` for the τ rungs to exist; otherwise the ladder tops out
    /// at mode coarsening.
    pub fidelity_budget: f32,
    /// Device fused-chunk cap: the chunk size "force maximal fused chunks"
    /// coarsens to.
    pub s_max: usize,
}

impl Default for GovernorConfig {
    fn default() -> Self {
        GovernorConfig {
            alpha: 0.25,
            queue_high: 0.0,
            latency_high_ms: 0.0,
            dwell: 3,
            base_tau: 0.0,
            fidelity_budget: 0.0,
            s_max: DEFAULT_FUSE_CHUNK,
        }
    }
}

/// Mutable governor state, guarded by one mutex (observe is called at block
/// cadence, never in a per-token loop).
struct GovState {
    queue_ewma: Option<f64>,
    lat_ewma_ms: Option<f64>,
    over: usize,
    under: usize,
    level: usize,
}

/// Quality-elastic overload governor (`serve --elastic`): watches
/// queue-depth and accepted-latency EWMAs and walks a degradation ladder,
/// trading reconstruction fidelity for throughput *only while pressure
/// lasts*:
///
/// | level | action |
/// |-------|--------|
/// | 0 | passthrough — decode options untouched, τ = `base_tau`, bit-exact |
/// | 1 | force maximal fused chunks (`S = s_max`) on every Jacobi-family block |
/// | 2 | additionally halve GS window counts (fewer, coarser sweeps) |
/// | 3.. | raise τ in [`GOVERNOR_TAU_STEPS`] increments toward `fidelity_budget` |
///
/// Levels 1–2 are *free* fidelity-wise at τ=0 (Prop 3.2: the per-block fixed
/// point is independent of sweep schedule), they only trade per-iteration
/// sync cadence for convergence slack; τ rungs genuinely spend quality and
/// are bounded by `--fidelity-budget`. Steps require `dwell` consecutive
/// over/under observations (tuner-style hysteresis), and the under
/// threshold is half the over threshold so the ladder never flaps across
/// one boundary. When pressure clears the governor walks back to level 0,
/// whose applied options are the exact configured ones.
///
/// Exported state: `sjd_degrade_level` and `sjd_elastic_tau` (τ × 1e6,
/// gauges are integers) move on every ladder step.
pub struct OverloadGovernor {
    cfg: GovernorConfig,
    /// Flow blocks `K` — ladder levels expand the configured policy into an
    /// explicit [`DecodePolicy::PerBlock`] over all decode positions.
    blocks: usize,
    state: Mutex<GovState>,
    level_gauge: Arc<crate::metrics::Gauge>,
    tau_gauge: Arc<crate::metrics::Gauge>,
}

impl std::fmt::Debug for OverloadGovernor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OverloadGovernor")
            .field("cfg", &self.cfg)
            .field("blocks", &self.blocks)
            .field("level", &self.level())
            .finish()
    }
}

impl OverloadGovernor {
    /// Build a governor for a `blocks`-block flow and publish its initial
    /// (healthy) state to `registry`.
    pub fn new(blocks: usize, cfg: GovernorConfig, registry: &crate::metrics::Registry) -> Self {
        let g = OverloadGovernor {
            cfg,
            blocks,
            state: Mutex::new(GovState {
                queue_ewma: None,
                lat_ewma_ms: None,
                over: 0,
                under: 0,
                level: 0,
            }),
            level_gauge: registry.gauge("sjd_degrade_level"),
            tau_gauge: registry.gauge("sjd_elastic_tau"),
        };
        g.publish(0);
        g
    }

    /// Highest ladder level: two mode-coarsening rungs, plus the τ rungs
    /// when the budget actually allows raising τ.
    fn max_level(&self) -> usize {
        2 + if self.cfg.fidelity_budget > self.cfg.base_tau { GOVERNOR_TAU_STEPS } else { 0 }
    }

    /// The τ the ladder runs at `level`. Level 0 returns `base_tau`
    /// *exactly* (no arithmetic), preserving bit-exactness on recovery.
    fn tau_at(&self, level: usize) -> f32 {
        if level <= 2 {
            return self.cfg.base_tau;
        }
        let frac = (level - 2) as f32 / GOVERNOR_TAU_STEPS as f32;
        self.cfg.base_tau + (self.cfg.fidelity_budget - self.cfg.base_tau) * frac
    }

    /// Current ladder level (0 = healthy passthrough).
    pub fn level(&self) -> usize {
        self.state.lock().unwrap().level
    }

    /// The τ decodes currently run at.
    pub fn effective_tau(&self) -> f32 {
        self.tau_at(self.level())
    }

    fn publish(&self, level: usize) {
        self.level_gauge.set(level as i64);
        self.tau_gauge.set((self.tau_at(level) as f64 * 1e6).round() as i64);
    }

    /// Feed one pressure observation: the batcher queue depth now, and the
    /// latency of a just-completed accepted request (if one completed).
    /// Steps the ladder at most one level per call, after `dwell`
    /// consecutive same-direction observations.
    pub fn observe(&self, queue_depth: usize, latency: Option<Duration>) {
        self.observe_inner(Some(queue_depth as f64), latency.map(|l| l.as_secs_f64() * 1e3));
    }

    /// Latency-only observation — the completion side of the feedback loop
    /// (final pipeline stage), which sees request latencies but not the
    /// batcher queue.
    pub fn observe_latency(&self, latency: Duration) {
        self.observe_inner(None, Some(latency.as_secs_f64() * 1e3));
    }

    fn observe_inner(&self, queue_depth: Option<f64>, latency_ms: Option<f64>) {
        let a = self.cfg.alpha;
        let fold = |prev: Option<f64>, x: f64| prev.map_or(x, |p| p + a * (x - p));
        let mut s = self.state.lock().unwrap();
        if let Some(depth) = queue_depth {
            s.queue_ewma = Some(fold(s.queue_ewma, depth));
        }
        if let Some(lat) = latency_ms {
            s.lat_ewma_ms = Some(fold(s.lat_ewma_ms, lat));
        }
        let mut over = false;
        let mut under = true;
        if self.cfg.queue_high > 0.0 {
            // No depth sample yet is neutral, like the latency signal below.
            if let Some(q) = s.queue_ewma {
                over |= q > self.cfg.queue_high;
                under &= q <= self.cfg.queue_high / 2.0;
            }
        }
        if self.cfg.latency_high_ms > 0.0 {
            // No latency sample yet is neutral, not "healthy": only an
            // actual below-band EWMA argues for stepping down.
            if let Some(l) = s.lat_ewma_ms {
                over |= l > self.cfg.latency_high_ms;
                under &= l <= self.cfg.latency_high_ms / 2.0;
            }
        }
        if self.cfg.queue_high <= 0.0 && self.cfg.latency_high_ms <= 0.0 {
            return; // both signals disabled: the governor never engages
        }
        if over {
            s.over += 1;
            s.under = 0;
            if s.over >= self.cfg.dwell && s.level < self.max_level() {
                s.level += 1;
                s.over = 0;
                self.publish(s.level);
            }
        } else if under {
            s.under += 1;
            s.over = 0;
            if s.under >= self.cfg.dwell && s.level > 0 {
                s.level -= 1;
                s.under = 0;
                self.publish(s.level);
            }
        } else {
            // Inside the hysteresis band: hold the level, reset streaks.
            s.over = 0;
            s.under = 0;
        }
    }

    /// Rewrite decode options for the current ladder level. Level 0 is a
    /// plain clone — callers on the healthy path pay nothing and decode the
    /// exact configured options.
    pub fn apply(&self, options: &SampleOptions) -> SampleOptions {
        let level = self.level();
        if level == 0 {
            return options.clone();
        }
        let mut out = options.clone();
        let modes = (0..self.blocks)
            .map(|pos| degrade_mode(options.policy.block_mode(pos, self.blocks), level, self.cfg.s_max))
            .collect();
        out.policy = DecodePolicy::PerBlock { modes };
        if level > 2 {
            out.jacobi.tau = self.tau_at(level);
        }
        out
    }
}

/// One block mode coarsened to a ladder level (level ≥ 1). Sequential blocks
/// stay sequential — they are pinned for correctness (paper §3.5 low-
/// redundancy layers), not a throughput choice the governor may override.
fn degrade_mode(mode: BlockDecode, level: usize, s_max: usize) -> BlockDecode {
    let s = s_max.max(1);
    let windows = |w: usize| if level >= 2 { (w / 2).max(1) } else { w };
    match mode {
        BlockDecode::Sequential => BlockDecode::Sequential,
        BlockDecode::Jacobi | BlockDecode::Fused { .. } => BlockDecode::Fused { chunk: s },
        BlockDecode::GsJacobi { windows: w } | BlockDecode::GsFused { windows: w, .. } => {
            match windows(w) {
                1 => BlockDecode::Fused { chunk: s },
                w => BlockDecode::GsFused { windows: w, chunk: s },
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_variants() {
        assert_eq!(DecodePolicy::parse("sequential"), Some(DecodePolicy::Sequential));
        assert_eq!(DecodePolicy::parse("ujd"), Some(DecodePolicy::UniformJacobi));
        assert_eq!(
            DecodePolicy::parse("selective"),
            Some(DecodePolicy::Selective { seq_blocks: 1 })
        );
        assert_eq!(
            DecodePolicy::parse("selective:2"),
            Some(DecodePolicy::Selective { seq_blocks: 2 })
        );
        assert_eq!(
            DecodePolicy::parse("gs"),
            Some(DecodePolicy::GsJacobi { windows: DEFAULT_GS_WINDOWS })
        );
        assert_eq!(DecodePolicy::parse("gs:8"), Some(DecodePolicy::GsJacobi { windows: 8 }));
        assert_eq!(
            DecodePolicy::parse("fuse"),
            Some(DecodePolicy::Fused { chunk: DEFAULT_FUSE_CHUNK })
        );
        assert_eq!(DecodePolicy::parse("fuse:4"), Some(DecodePolicy::Fused { chunk: 4 }));
        assert_eq!(DecodePolicy::parse("wat"), None);
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "", "Sequential", "SJD", "selective:", "selective:x", "selective:-1",
            "selective:1.5", "gs:", "gs:0", "gs:abc", "gs:-2", "gs :4", "ujd ",
            "@", "custom", "fuse:", "fuse:0", "fuse:x", "fuse:-3", "fuse :2",
        ] {
            assert_eq!(DecodePolicy::parse(bad), None, "'{bad}' must be rejected");
        }
    }

    #[test]
    fn init_strategy_parse_rejects_malformed() {
        use super::super::jacobi::InitStrategy;
        for bad in ["", "Zeros", "NORMAL", "prev-layer", "zeros ", "random", "0"] {
            assert_eq!(InitStrategy::parse(bad), None, "'{bad}' must be rejected");
        }
    }

    #[test]
    fn selective_matches_paper() {
        // Paper: sequential on the first layer only, Jacobi on the rest.
        let p = DecodePolicy::Selective { seq_blocks: 1 };
        assert!(!p.use_jacobi(0, 4));
        assert!(p.use_jacobi(1, 4));
        assert!(p.use_jacobi(3, 4));
    }

    #[test]
    fn uniform_and_sequential() {
        assert!(DecodePolicy::UniformJacobi.use_jacobi(0, 4));
        assert!(!DecodePolicy::Sequential.use_jacobi(3, 4));
    }

    #[test]
    fn custom_mask() {
        let p = DecodePolicy::Custom { jacobi_mask: vec![false, true, false] };
        assert!(!p.use_jacobi(0, 3));
        assert!(p.use_jacobi(1, 3));
        assert!(!p.use_jacobi(2, 3));
    }

    fn mk_stats(block: usize, iters: usize, ms: u64, converged: bool) -> JacobiStats {
        JacobiStats {
            block,
            iterations: iters,
            wall: Duration::from_millis(ms),
            residuals: vec![],
            converged,
            host_syncs: iters,
        }
    }

    #[test]
    fn calibrate_prefers_faster_converged() {
        let mk = mk_stats;
        let jacobi = vec![
            mk(0, 64, 900, true),  // slower than seq → sequential
            mk(1, 5, 50, true),    // faster → jacobi
            mk(2, 64, 10, false),  // failed to converge → sequential
        ];
        let seq = vec![
            Duration::from_millis(500),
            Duration::from_millis(500),
            Duration::from_millis(500),
        ];
        let p = calibrate(&jacobi, &seq);
        assert_eq!(
            p,
            DecodePolicy::Custom { jacobi_mask: vec![false, true, false] }
        );
    }

    #[test]
    fn json_roundtrip_all_variants() {
        for p in [
            DecodePolicy::Sequential,
            DecodePolicy::UniformJacobi,
            DecodePolicy::Selective { seq_blocks: 2 },
            DecodePolicy::GsJacobi { windows: 6 },
            DecodePolicy::Fused { chunk: 5 },
            DecodePolicy::Custom { jacobi_mask: vec![false, true, true] },
            DecodePolicy::PerBlock {
                modes: vec![
                    BlockDecode::Sequential,
                    BlockDecode::Jacobi,
                    BlockDecode::GsJacobi { windows: 8 },
                    BlockDecode::Fused { chunk: 3 },
                    BlockDecode::GsFused { windows: 4, chunk: 2 },
                ],
            },
        ] {
            let j = p.to_json();
            let back = DecodePolicy::from_json(&j).unwrap();
            assert_eq!(p, back);
        }
    }

    #[test]
    fn json_rejects_bad_gs_windows() {
        use crate::jsonx::Value;
        let v = Value::obj(vec![("kind", Value::str("gs")), ("windows", Value::num(0.0))]);
        assert!(DecodePolicy::from_json(&v).is_err());
        // Present-but-malformed must error, never silently default.
        for bad in [Value::num(2.5), Value::num(-3.0), Value::str("four")] {
            let v = Value::obj(vec![("kind", Value::str("gs")), ("windows", bad)]);
            assert!(DecodePolicy::from_json(&v).is_err());
        }
        // Absent windows falls back to the documented default.
        let v = Value::obj(vec![("kind", Value::str("gs"))]);
        assert_eq!(
            DecodePolicy::from_json(&v).unwrap(),
            DecodePolicy::GsJacobi { windows: DEFAULT_GS_WINDOWS }
        );
        let modes = Value::Arr(vec![Value::obj(vec![("mode", Value::str("warp"))])]);
        let v = Value::obj(vec![("kind", Value::str("per_block")), ("modes", modes)]);
        assert!(DecodePolicy::from_json(&v).is_err());
    }

    #[test]
    fn json_rejects_bad_fuse_chunk() {
        use crate::jsonx::Value;
        for bad in [Value::num(0.0), Value::num(1.5), Value::num(-2.0), Value::str("two")] {
            let v = Value::obj(vec![("kind", Value::str("fuse")), ("chunk", bad)]);
            assert!(DecodePolicy::from_json(&v).is_err());
        }
        // Absent chunk falls back to the documented default.
        let v = Value::obj(vec![("kind", Value::str("fuse"))]);
        assert_eq!(
            DecodePolicy::from_json(&v).unwrap(),
            DecodePolicy::Fused { chunk: DEFAULT_FUSE_CHUNK }
        );
        // Same strictness on the per-block gs_fuse mode.
        let modes = Value::Arr(vec![Value::obj(vec![
            ("mode", Value::str("gs_fuse")),
            ("chunk", Value::num(0.0)),
        ])]);
        let v = Value::obj(vec![("kind", Value::str("per_block")), ("modes", modes)]);
        assert!(DecodePolicy::from_json(&v).is_err());
    }

    #[test]
    fn fused_policy_block_mode_and_label() {
        let p = DecodePolicy::Fused { chunk: 6 };
        assert_eq!(p.block_mode(0, 4), BlockDecode::Fused { chunk: 6 });
        assert!(p.use_jacobi(0, 4), "fused decode is a Jacobi-family mode");
        assert_eq!(p.label(), "Fused(S=6)");
    }

    #[test]
    fn calibrate_chunks_seeds_from_iteration_traces() {
        let mk = mk_stats;
        let seq_len = 64;
        let jacobi = vec![
            mk(0, 60, 100, true),  // hard: max windows, per-window chunk share
            mk(1, 4, 100, true),   // easy: plain fused, chunk = measured iters
            mk(2, 64, 100, false), // no converge → sequential, untouched
            mk(3, 2, 900, true),   // slower than sequential → sequential
        ];
        let seq = vec![Duration::from_millis(500); 4];
        let p = calibrate_chunks(&jacobi, &seq, seq_len, 8, 8);
        assert_eq!(
            p,
            DecodePolicy::PerBlock {
                modes: vec![
                    // 60/64 · 8 → 8 windows; ⌈60/8⌉ = 8 chunk share.
                    BlockDecode::GsFused { windows: 8, chunk: 8 },
                    BlockDecode::Fused { chunk: 4 },
                    BlockDecode::Sequential,
                    BlockDecode::Sequential,
                ],
            }
        );
        // s_max caps every learned chunk: the same traces under a shorter
        // fused history never schedule past the device cap.
        let p = calibrate_chunks(&jacobi, &seq, seq_len, 8, 2);
        let DecodePolicy::PerBlock { modes } = p else { unreachable!() };
        assert_eq!(modes[0], BlockDecode::GsFused { windows: 8, chunk: 2 });
        assert_eq!(modes[1], BlockDecode::Fused { chunk: 2 });
        // JSON round-trip covers the learned fused modes.
        let p = calibrate_chunks(&jacobi, &seq, seq_len, 8, 8);
        assert_eq!(DecodePolicy::from_json(&p.to_json()).unwrap(), p);
    }

    #[test]
    fn block_modes_per_policy() {
        let gs = DecodePolicy::GsJacobi { windows: 3 };
        assert_eq!(gs.block_mode(0, 4), BlockDecode::GsJacobi { windows: 3 });
        assert!(gs.use_jacobi(0, 4));

        let pb = DecodePolicy::PerBlock {
            modes: vec![
                BlockDecode::Sequential,
                BlockDecode::GsJacobi { windows: 2 },
                BlockDecode::Jacobi,
            ],
        };
        assert_eq!(pb.block_mode(0, 4), BlockDecode::Sequential);
        assert_eq!(pb.block_mode(1, 4), BlockDecode::GsJacobi { windows: 2 });
        assert_eq!(pb.block_mode(2, 4), BlockDecode::Jacobi);
        // Positions past the learned vector default to Jacobi (like Custom).
        assert_eq!(pb.block_mode(3, 4), BlockDecode::Jacobi);
        assert!(!pb.use_jacobi(0, 4));
        assert!(pb.use_jacobi(1, 4));
    }

    #[test]
    fn calibrate_windows_scales_with_iteration_ratio() {
        let mk = mk_stats;
        let seq_len = 64;
        let jacobi = vec![
            mk(0, 60, 100, true),  // hard: t ≈ L → max windows
            mk(1, 4, 100, true),   // easy: t ≪ L → plain Jacobi
            mk(2, 32, 100, true),  // middling → intermediate window count
            mk(3, 64, 100, false), // no converge → sequential
            mk(4, 4, 900, true),   // slower than sequential → sequential
        ];
        let seq = vec![Duration::from_millis(500); 5];
        let p = calibrate_windows(&jacobi, &seq, seq_len, 8);
        assert_eq!(
            p,
            DecodePolicy::PerBlock {
                modes: vec![
                    BlockDecode::GsJacobi { windows: 8 },
                    BlockDecode::Jacobi,
                    BlockDecode::GsJacobi { windows: 4 },
                    BlockDecode::Sequential,
                    BlockDecode::Sequential,
                ],
            }
        );
        assert_eq!(p.label(), "Adaptive-GS");
    }

    #[test]
    fn parse_or_load_file() {
        let p = DecodePolicy::Custom { jacobi_mask: vec![false, true] };
        let path = std::env::temp_dir().join("sjd_policy_test.json");
        std::fs::write(&path, crate::jsonx::to_string_pretty(&p.to_json())).unwrap();
        let loaded =
            DecodePolicy::parse_or_load(&format!("@{}", path.display())).unwrap();
        assert_eq!(loaded, p);
        // Plain strings still parse.
        assert_eq!(
            DecodePolicy::parse_or_load("ujd").unwrap(),
            DecodePolicy::UniformJacobi
        );
        assert!(DecodePolicy::parse_or_load("nope").is_err());
    }

    #[test]
    fn labels() {
        assert_eq!(DecodePolicy::Sequential.label(), "Sequential");
        assert_eq!(DecodePolicy::Selective { seq_blocks: 1 }.label(), "SJD");
        assert_eq!(DecodePolicy::UniformJacobi.label(), "UJD");
    }

    #[test]
    fn mode_for_iters_shared_law() {
        assert_eq!(mode_for_iters(1, 64, 8, None), BlockDecode::Jacobi);
        assert_eq!(mode_for_iters(4, 64, 8, None), BlockDecode::Jacobi); // 0.5 rounds up to W=1
        assert_eq!(mode_for_iters(32, 64, 8, None), BlockDecode::GsJacobi { windows: 4 });
        assert_eq!(mode_for_iters(60, 64, 8, None), BlockDecode::GsJacobi { windows: 8 });
        assert_eq!(mode_for_iters(4, 64, 8, Some(8)), BlockDecode::Fused { chunk: 4 });
        assert_eq!(
            mode_for_iters(60, 64, 8, Some(8)),
            BlockDecode::GsFused { windows: 8, chunk: 8 }
        );
        // s_max caps the chunk; 0 iterations clamp to 1.
        assert_eq!(mode_for_iters(6, 64, 8, Some(2)), BlockDecode::Fused { chunk: 2 });
        assert_eq!(mode_for_iters(0, 64, 8, None), BlockDecode::Jacobi);
    }

    /// Property-style sweep (satellite contract): pseudo-random policies —
    /// every variant, nested `PerBlock` fused modes included — round-trip
    /// through JSON, have total non-empty labels, and (where a CLI spelling
    /// exists) round-trip through `parse`; malformed strings are rejected.
    #[test]
    fn property_random_policies_roundtrip_json_parse_and_label() {
        use crate::tensor::Pcg64;

        fn rand_mode(rng: &mut Pcg64) -> BlockDecode {
            match rng.next_below(5) {
                0 => BlockDecode::Sequential,
                1 => BlockDecode::Jacobi,
                2 => BlockDecode::GsJacobi { windows: 1 + rng.next_below(16) },
                3 => BlockDecode::Fused { chunk: 1 + rng.next_below(8) },
                _ => BlockDecode::GsFused {
                    windows: 1 + rng.next_below(16),
                    chunk: 1 + rng.next_below(8),
                },
            }
        }

        let mut rng = Pcg64::seed(0xA11CE);
        for case in 0..300 {
            let p = match rng.next_below(7) {
                0 => DecodePolicy::Sequential,
                1 => DecodePolicy::UniformJacobi,
                2 => DecodePolicy::Selective { seq_blocks: rng.next_below(9) },
                3 => DecodePolicy::GsJacobi { windows: 1 + rng.next_below(32) },
                4 => DecodePolicy::Fused { chunk: 1 + rng.next_below(8) },
                5 => DecodePolicy::Custom {
                    jacobi_mask: (0..rng.next_below(9)).map(|_| rng.next_below(2) == 1).collect(),
                },
                _ => DecodePolicy::PerBlock {
                    modes: (0..1 + rng.next_below(9)).map(|_| rand_mode(&mut rng)).collect(),
                },
            };
            assert_eq!(
                DecodePolicy::from_json(&p.to_json()).unwrap(),
                p,
                "JSON round-trip, case {case}"
            );
            assert!(!p.label().is_empty(), "label must be total, case {case}");
            let spelling = match &p {
                DecodePolicy::Sequential => Some("sequential".to_string()),
                DecodePolicy::UniformJacobi => Some("ujd".into()),
                DecodePolicy::Selective { seq_blocks } => Some(format!("selective:{seq_blocks}")),
                DecodePolicy::GsJacobi { windows } => Some(format!("gs:{windows}")),
                DecodePolicy::Fused { chunk } => Some(format!("fuse:{chunk}")),
                _ => None, // calibrated policies have no CLI spelling (JSON only)
            };
            if let Some(s) = spelling {
                assert_eq!(DecodePolicy::parse(&s), Some(p.clone()), "parse('{s}')");
            }
        }
        for bad in ["gs:4x", "fuse:8 ", "per_block", "selective::2", "gs::", "jacobi:2"] {
            assert_eq!(DecodePolicy::parse(bad), None, "'{bad}' must be rejected");
        }
    }

    #[test]
    fn block_decode_describe() {
        assert_eq!(BlockDecode::Sequential.describe(), "sequential");
        assert_eq!(BlockDecode::Jacobi.describe(), "jacobi");
        assert_eq!(BlockDecode::GsJacobi { windows: 4 }.describe(), "gs W=4");
        assert_eq!(BlockDecode::Fused { chunk: 3 }.describe(), "fuse S=3");
        assert_eq!(
            BlockDecode::GsFused { windows: 8, chunk: 4 }.describe(),
            "gs_fuse W=8 S=4"
        );
    }

    // -- PolicyTuner ---------------------------------------------------------

    use super::super::sampler::BlockTrace;
    use crate::runtime::HostTensor;

    /// One synthetic decode output: full-sequence Jacobi traces with the
    /// given per-position iteration counts (L = 8 to match the mock flow).
    fn mk_output(iters_per_pos: &[usize], converged: bool) -> SampleOutput {
        let blocks = iters_per_pos.len();
        let traces = iters_per_pos
            .iter()
            .enumerate()
            .map(|(pos, &it)| BlockTrace {
                block: blocks - 1 - pos,
                position: pos,
                used_jacobi: true,
                steps: it,
                position_updates: it * 8,
                host_syncs: it,
                wall: Duration::from_millis(1),
                jacobi: Some(JacobiStats {
                    block: blocks - 1 - pos,
                    iterations: it,
                    wall: Duration::from_millis(1),
                    residuals: vec![],
                    converged,
                    host_syncs: it,
                }),
                gs: None,
                init: InitStrategy::Zeros,
                spec_hit: false,
                spec_cost_updates: 0,
            })
            .collect();
        SampleOutput {
            tokens: HostTensor::f32(&[1], vec![0.0]),
            traces,
            total_wall: Duration::ZERO,
            other_wall: Duration::ZERO,
        }
    }

    fn tuner_cfg() -> TunerConfig {
        TunerConfig {
            alpha: 0.5,
            max_windows: 8,
            s_max: 4,
            min_obs: 2,
            probe_every: 0,
            dwell: 2,
        }
    }

    #[test]
    fn tuner_bootstraps_probes_then_applies_the_calibration_law() {
        let t = PolicyTuner::new(4, 8, DecodePolicy::Selective { seq_blocks: 1 }, tuner_cfg());
        // Before any observation: pinned-sequential position 0, probe mode
        // (full-sequence fused measurement) everywhere else.
        let p = t.policy_for(2);
        assert_eq!(p.block_mode(0, 4), BlockDecode::Sequential);
        for pos in 1..4 {
            assert_eq!(p.block_mode(pos, 4), BlockDecode::Fused { chunk: 4 });
        }
        // Stable traffic: pos 1 converges in 2 iters, pos 2 in 6, pos 3 in 3.
        for _ in 0..4 {
            let _ = t.policy_for(2);
            t.observe(2, &mk_output(&[8, 2, 6, 3], true));
        }
        let DecodePolicy::PerBlock { modes } = t.snapshot(2).unwrap() else { unreachable!() };
        // L = 8, W_max = 8 ⇒ windows = t; chunk = ⌈t/W⌉ = 1 — exactly
        // mode_for_iters, the law calibrate_chunks uses offline.
        assert_eq!(
            modes,
            vec![
                BlockDecode::Sequential, // bootstrap-pinned, never tuned
                BlockDecode::GsFused { windows: 2, chunk: 1 },
                BlockDecode::GsFused { windows: 6, chunk: 1 },
                BlockDecode::GsFused { windows: 3, chunk: 1 },
            ]
        );
        // Tuned policy now routes decodes (probing disabled in this config).
        let p = t.policy_for(2);
        assert_eq!(p.block_mode(1, 4), BlockDecode::GsFused { windows: 2, chunk: 1 });
        // Buckets tune independently: a fresh bucket is still bootstrapping.
        assert_eq!(t.policy_for(8).block_mode(1, 4), BlockDecode::Fused { chunk: 4 });
    }

    #[test]
    fn tuner_probe_cadence_remeasure_tuned_blocks() {
        let cfg = TunerConfig { min_obs: 1, dwell: 1, probe_every: 4, ..tuner_cfg() };
        let t = PolicyTuner::new(2, 8, DecodePolicy::UniformJacobi, cfg);
        let _ = t.policy_for(1); // decodes = 1 (bootstrap probe)
        t.observe(1, &mk_output(&[6, 6], true));
        let tuned = BlockDecode::GsFused { windows: 6, chunk: 1 };
        // decodes 2, 3 → tuned; decode 4 → probe; 5..=7 tuned; 8 → probe.
        let mut saw = Vec::new();
        for _ in 0..7 {
            saw.push(t.policy_for(1).block_mode(0, 2));
        }
        assert_eq!(
            saw,
            vec![
                tuned,
                tuned,
                BlockDecode::Fused { chunk: 4 },
                tuned,
                tuned,
                tuned,
                BlockDecode::Fused { chunk: 4 },
            ]
        );
    }

    #[test]
    fn tuner_hysteresis_requires_dwell_consecutive_derivations() {
        let cfg = TunerConfig { alpha: 1.0, min_obs: 1, dwell: 3, ..tuner_cfg() };
        let t = PolicyTuner::new(1, 8, DecodePolicy::UniformJacobi, cfg);
        t.observe(4, &mk_output(&[2], true));
        let first = BlockDecode::GsFused { windows: 2, chunk: 1 };
        assert_eq!(t.snapshot(4).unwrap().block_mode(0, 1), first);
        // A changed regime (t = 8) must persist for `dwell` measurements
        // before the applied mode moves.
        t.observe(4, &mk_output(&[8], true));
        assert_eq!(t.snapshot(4).unwrap().block_mode(0, 1), first);
        t.observe(4, &mk_output(&[8], true));
        assert_eq!(t.snapshot(4).unwrap().block_mode(0, 1), first);
        t.observe(4, &mk_output(&[8], true));
        assert_eq!(
            t.snapshot(4).unwrap().block_mode(0, 1),
            BlockDecode::GsFused { windows: 8, chunk: 1 }
        );
        // A single flicker back does not flap the policy …
        t.observe(4, &mk_output(&[2], true));
        assert_eq!(
            t.snapshot(4).unwrap().block_mode(0, 1),
            BlockDecode::GsFused { windows: 8, chunk: 1 }
        );
        // … and an interrupted candidate streak starts counting over.
        t.observe(4, &mk_output(&[8], true));
        t.observe(4, &mk_output(&[2], true));
        t.observe(4, &mk_output(&[2], true));
        t.observe(4, &mk_output(&[2], true));
        assert_eq!(t.snapshot(4).unwrap().block_mode(0, 1), first);
    }

    #[test]
    fn tuner_nonconverged_probes_derive_sequential() {
        let cfg = TunerConfig { min_obs: 1, dwell: 1, ..tuner_cfg() };
        let t = PolicyTuner::new(1, 8, DecodePolicy::UniformJacobi, cfg);
        t.observe(2, &mk_output(&[8], false));
        assert_eq!(t.snapshot(2).unwrap().block_mode(0, 1), BlockDecode::Sequential);
    }

    #[test]
    fn tuner_ignores_windowed_and_sequential_traces() {
        let t = PolicyTuner::new(2, 8, DecodePolicy::UniformJacobi, tuner_cfg());
        let mut out = mk_output(&[4, 4], true);
        out.traces[0].jacobi = None; // e.g. a GS trace: no full-sequence stats
        out.traces[1].used_jacobi = false;
        out.traces[1].jacobi = None;
        t.observe(2, &out);
        // Nothing measurable arrived: still bootstrapping (probe mode).
        assert_eq!(t.policy_for(2).block_mode(0, 2), BlockDecode::Fused { chunk: 4 });
        assert_eq!(t.snapshot(2).unwrap().block_mode(0, 2), BlockDecode::Jacobi);
    }

    /// One synthetic decode under a given init provider: same iteration
    /// shape as [`mk_output`], with every trace stamped with the provider
    /// and its per-block speculation cost.
    fn mk_output_init(
        iters_per_pos: &[usize],
        init: InitStrategy,
        spec_cost: usize,
    ) -> SampleOutput {
        let mut out = mk_output(iters_per_pos, true);
        for t in &mut out.traces {
            t.init = init;
            t.spec_hit = init.is_speculative();
            t.spec_cost_updates = spec_cost;
        }
        out
    }

    #[test]
    fn init_policy_parse_label_roundtrip() {
        for s in ["zeros", "normal", "prev", "proj", "draft", "warm", "warm:8"] {
            let p = InitPolicy::parse(s).unwrap_or_else(|| panic!("'{s}' must parse"));
            assert_eq!(InitPolicy::parse(&p.label()), Some(p), "label('{s}') must re-parse");
        }
        assert_eq!(
            InitPolicy::parse("warm:8"),
            Some(InitPolicy { strategy: InitStrategy::Warm, warm_cap: 8 })
        );
        assert_eq!(InitPolicy::parse("warm").unwrap().warm_cap, DEFAULT_WARM_CAP);
        for bad in ["", "warm:", "warm:0", "warm:x", "warm:-2", "proj:4", "spec", "Zeros"] {
            assert_eq!(InitPolicy::parse(bad), None, "'{bad}' must be rejected");
        }
    }

    #[test]
    fn init_policy_json_roundtrip_and_strictness() {
        use crate::jsonx::Value;
        for s in ["zeros", "normal", "prev", "proj", "draft", "warm", "warm:5"] {
            let p = InitPolicy::parse(s).unwrap();
            assert_eq!(InitPolicy::from_json(&p.to_json()).unwrap(), p, "round-trip '{s}'");
        }
        // Absent warm_cap falls back to the documented default …
        let v = Value::obj(vec![("strategy", Value::str("warm"))]);
        assert_eq!(InitPolicy::from_json(&v).unwrap().warm_cap, DEFAULT_WARM_CAP);
        // … but a present-and-malformed one is an error, and so is an
        // unknown strategy.
        for bad in [Value::num(0.0), Value::num(2.5), Value::num(-1.0), Value::str("big")] {
            let v = Value::obj(vec![("strategy", Value::str("warm")), ("warm_cap", bad)]);
            assert!(InitPolicy::from_json(&v).is_err());
        }
        let v = Value::obj(vec![("strategy", Value::str("psychic"))]);
        assert!(InitPolicy::from_json(&v).is_err());
        assert!(InitPolicy::from_json(&Value::obj(vec![])).is_err());
    }

    #[test]
    fn tuner_init_passthrough_for_non_speculative_strategies() {
        let t = PolicyTuner::new(1, 8, DecodePolicy::UniformJacobi, tuner_cfg())
            .with_init(InitStrategy::Normal);
        for _ in 0..5 {
            assert_eq!(t.init_for(2), InitStrategy::Normal);
        }
        // Default construction gates nothing and wastes nothing.
        let t = PolicyTuner::new(1, 8, DecodePolicy::UniformJacobi, tuner_cfg());
        assert_eq!(t.init_for(2), InitStrategy::Zeros);
        assert_eq!(t.observe(2, &mk_output(&[4], true)), 0);
    }

    #[test]
    fn tuner_init_reverts_bucket_when_savings_go_negative() {
        let cfg = TunerConfig { min_obs: 2, probe_every: 0, ..tuner_cfg() };
        let t = PolicyTuner::new(1, 8, DecodePolicy::UniformJacobi, cfg)
            .with_init(InitStrategy::Draft);
        // Bootstrap: the provider is applied while evidence accumulates.
        assert_eq!(t.init_for(3), InitStrategy::Draft);
        // Zeros baseline: 8 iterations → 64 position-updates.
        assert_eq!(t.observe(3, &mk_output_init(&[8], InitStrategy::Zeros, 0)), 0);
        t.observe(3, &mk_output_init(&[8], InitStrategy::Zeros, 0));
        // Draft decodes: the same 64 refine updates plus a 72-update draft
        // pass — realized savings are negative and the waste is reported.
        let wasted = t.observe(3, &mk_output_init(&[8], InitStrategy::Draft, 72));
        assert_eq!(wasted, 72, "cost above the baseline estimate is waste");
        t.observe(3, &mk_output_init(&[8], InitStrategy::Draft, 72));
        assert_eq!(t.init_for(3), InitStrategy::Zeros, "bucket reverted to Zeros");
        // Buckets gate independently: a fresh bucket still runs the provider.
        assert_eq!(t.init_for(5), InitStrategy::Draft);
    }

    #[test]
    fn tuner_init_keeps_paying_provider_and_probes_baseline() {
        let cfg = TunerConfig { min_obs: 1, probe_every: 4, ..tuner_cfg() };
        let t = PolicyTuner::new(1, 8, DecodePolicy::UniformJacobi, cfg)
            .with_init(InitStrategy::Proj);
        t.observe(2, &mk_output_init(&[8], InitStrategy::Zeros, 0)); // 64 baseline
        let w = t.observe(2, &mk_output_init(&[7], InitStrategy::Proj, 0)); // 56: pays
        assert_eq!(w, 0, "a paying provider wastes nothing");
        let seen: Vec<_> = (0..8).map(|_| t.init_for(2)).collect();
        assert_eq!(
            seen,
            vec![
                InitStrategy::Proj,
                InitStrategy::Proj,
                InitStrategy::Proj,
                InitStrategy::Zeros, // every 4th decode: baseline probe
                InitStrategy::Proj,
                InitStrategy::Proj,
                InitStrategy::Proj,
                InitStrategy::Zeros,
            ]
        );
        // The /policy body reports the gate state.
        let j = t.to_json();
        let init = j.get("init").unwrap();
        assert_eq!(init.req_str("requested").unwrap(), "proj");
        let buckets = init.get("buckets").and_then(crate::jsonx::Value::as_obj).unwrap();
        assert_eq!(
            buckets.get("2").unwrap().get("active").and_then(crate::jsonx::Value::as_bool),
            Some(true)
        );
    }

    #[test]
    fn tuner_snapshot_best_and_json() {
        let cfg = TunerConfig { min_obs: 1, dwell: 1, ..tuner_cfg() };
        let t = PolicyTuner::new(2, 8, DecodePolicy::UniformJacobi, cfg);
        t.observe(2, &mk_output(&[3, 5], true));
        t.observe(4, &mk_output(&[3, 5], true));
        t.observe(4, &mk_output(&[3, 5], true));
        let (bucket, policy) = t.snapshot_best().unwrap();
        assert_eq!(bucket, 4, "most-observed bucket wins");
        // The snapshot is the existing policy-JSON format — it loads back.
        assert_eq!(DecodePolicy::from_json(&policy.to_json()).unwrap(), policy);
        let j = t.to_json();
        assert_eq!(j.req_str("source").unwrap(), "tuner");
        assert_eq!(j.req_usize("blocks").unwrap(), 2);
        let buckets = j.get("buckets").and_then(crate::jsonx::Value::as_obj).unwrap();
        assert_eq!(buckets.len(), 2);
        let rows = buckets.get("4").and_then(crate::jsonx::Value::as_arr).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].req_usize("observations").unwrap(), 2);
        assert!(rows[0].get("ewma_iters").and_then(crate::jsonx::Value::as_f64).is_some());
    }

    // -- OverloadGovernor ----------------------------------------------------

    use super::super::jacobi::JacobiConfig;

    fn gov_opts() -> SampleOptions {
        SampleOptions {
            policy: DecodePolicy::Selective { seq_blocks: 1 },
            jacobi: JacobiConfig { tau: 0.0, ..JacobiConfig::default() },
            mask_o: 0,
            fused_sequential: false,
            seed: 7,
        }
    }

    fn gov_cfg() -> GovernorConfig {
        GovernorConfig {
            alpha: 1.0, // instant EWMA: tests drive raw signals
            queue_high: 8.0,
            latency_high_ms: 0.0,
            dwell: 2,
            base_tau: 0.0,
            fidelity_budget: 0.3,
            s_max: 8,
        }
    }

    #[test]
    fn governor_idle_is_exact_passthrough() {
        let reg = crate::metrics::Registry::new();
        let g = OverloadGovernor::new(4, gov_cfg(), &reg);
        let opts = gov_opts();
        let applied = g.apply(&opts);
        assert_eq!(applied.policy, opts.policy, "level 0 must not rewrite the policy");
        assert_eq!(applied.jacobi.tau.to_bits(), opts.jacobi.tau.to_bits());
        assert_eq!(applied.seed, opts.seed);
        assert_eq!(applied.mask_o, opts.mask_o);
        assert_eq!(applied.fused_sequential, opts.fused_sequential);
        assert_eq!(reg.gauge("sjd_degrade_level").get(), 0);
        assert_eq!(reg.gauge("sjd_elastic_tau").get(), 0);
    }

    #[test]
    fn governor_steps_up_ladder_and_back_to_exact_base() {
        let reg = crate::metrics::Registry::new();
        let g = OverloadGovernor::new(4, gov_cfg(), &reg);
        // Sustained pressure: each dwell=2 pair of over-threshold
        // observations climbs one rung, to the top of the ladder (2 mode
        // rungs + 3 τ rungs) and no further.
        for expect in 1..=5usize {
            g.observe(32, None);
            g.observe(32, None);
            assert_eq!(g.level(), expect);
        }
        for _ in 0..4 {
            g.observe(32, None);
        }
        assert_eq!(g.level(), 5, "ladder is capped at max level");
        assert_eq!(reg.gauge("sjd_degrade_level").get(), 5);
        assert_eq!(reg.gauge("sjd_elastic_tau").get(), 300_000, "τ = budget at the top");
        assert!((g.effective_tau() - 0.3).abs() < 1e-6);
        // Pressure clears: walk all the way back down; the recovered τ is
        // bit-identical to the configured base (no float residue).
        while g.level() > 0 {
            g.observe(0, None);
        }
        assert_eq!(g.effective_tau().to_bits(), 0.0f32.to_bits());
        assert_eq!(reg.gauge("sjd_degrade_level").get(), 0);
        assert_eq!(reg.gauge("sjd_elastic_tau").get(), 0);
        let opts = gov_opts();
        assert_eq!(g.apply(&opts).policy, opts.policy, "recovered governor is passthrough");
    }

    #[test]
    fn governor_ladder_coarsens_modes_and_raises_tau() {
        let reg = crate::metrics::Registry::new();
        let g = OverloadGovernor::new(4, gov_cfg(), &reg);
        let mut opts = gov_opts();
        opts.policy = DecodePolicy::PerBlock {
            modes: vec![
                BlockDecode::Sequential,
                BlockDecode::Jacobi,
                BlockDecode::GsJacobi { windows: 4 },
                BlockDecode::GsFused { windows: 2, chunk: 2 },
            ],
        };
        // Level 1: maximal fused chunks, window counts untouched,
        // sequential blocks pinned.
        g.observe(32, None);
        g.observe(32, None);
        assert_eq!(g.level(), 1);
        let DecodePolicy::PerBlock { modes } = g.apply(&opts).policy else { unreachable!() };
        assert_eq!(
            modes,
            vec![
                BlockDecode::Sequential,
                BlockDecode::Fused { chunk: 8 },
                BlockDecode::GsFused { windows: 4, chunk: 8 },
                BlockDecode::GsFused { windows: 2, chunk: 8 },
            ]
        );
        assert_eq!(g.apply(&opts).jacobi.tau.to_bits(), 0.0f32.to_bits(), "τ untouched below level 3");
        // Level 2: windows halve (a 2-window block collapses to plain fused).
        g.observe(32, None);
        g.observe(32, None);
        assert_eq!(g.level(), 2);
        let DecodePolicy::PerBlock { modes } = g.apply(&opts).policy else { unreachable!() };
        assert_eq!(
            modes,
            vec![
                BlockDecode::Sequential,
                BlockDecode::Fused { chunk: 8 },
                BlockDecode::GsFused { windows: 2, chunk: 8 },
                BlockDecode::Fused { chunk: 8 },
            ]
        );
        // Level 3: first τ rung — base + (budget − base)/3.
        g.observe(32, None);
        g.observe(32, None);
        assert_eq!(g.level(), 3);
        assert!((g.apply(&opts).jacobi.tau - 0.1).abs() < 1e-6);
    }

    #[test]
    fn governor_dwell_prevents_flapping() {
        let reg = crate::metrics::Registry::new();
        let g = OverloadGovernor::new(2, gov_cfg(), &reg);
        // Alternating over/under never accumulates a dwell streak.
        for _ in 0..10 {
            g.observe(32, None);
            g.observe(0, None);
        }
        assert_eq!(g.level(), 0);
        // Mid-band observations (between high/2 and high) hold the level.
        g.observe(32, None);
        g.observe(32, None);
        assert_eq!(g.level(), 1);
        for _ in 0..10 {
            g.observe(6, None); // 4 < 6 ≤ 8: inside the hysteresis band
        }
        assert_eq!(g.level(), 1, "hysteresis band holds the ladder");
    }

    #[test]
    fn governor_without_budget_stops_at_mode_coarsening() {
        let reg = crate::metrics::Registry::new();
        let cfg = GovernorConfig { fidelity_budget: 0.0, ..gov_cfg() };
        let g = OverloadGovernor::new(2, cfg, &reg);
        for _ in 0..20 {
            g.observe(32, None);
        }
        assert_eq!(g.level(), 2, "no τ rungs without fidelity budget");
        assert_eq!(g.effective_tau().to_bits(), 0.0f32.to_bits());
    }

    #[test]
    fn governor_latency_signal_engages_ladder() {
        let reg = crate::metrics::Registry::new();
        let cfg = GovernorConfig { queue_high: 0.0, latency_high_ms: 50.0, ..gov_cfg() };
        let g = OverloadGovernor::new(2, cfg, &reg);
        // Queue signal disabled; depth alone must not engage.
        g.observe(1000, None);
        g.observe(1000, None);
        assert_eq!(g.level(), 0);
        g.observe(0, Some(Duration::from_millis(200)));
        g.observe(0, Some(Duration::from_millis(200)));
        assert_eq!(g.level(), 1);
        g.observe(0, Some(Duration::from_millis(1)));
        g.observe(0, Some(Duration::from_millis(1)));
        assert_eq!(g.level(), 0);
    }

    /// Satellite contract: fuzz the policy parsers ≥10k cases — no panics,
    /// and any JSON the parser accepts as a policy must round-trip.
    #[test]
    fn fuzz_policy_parsers_never_panic() {
        use crate::testkit::fuzz::fuzz_cases;
        let corpus: &[&[u8]] = &[
            b"sequential",
            b"selective:2",
            b"gs:8",
            b"fuse:4",
            br#"{"kind": "gs", "windows": 4}"#,
            br#"{"kind": "per_block", "modes": [{"mode": "gs_fuse", "windows": 8, "chunk": 4}]}"#,
            br#"{"strategy": "warm", "warm_cap": 8}"#,
        ];
        let dict: &[&[u8]] = &[
            b"kind", b"mode", b"modes", b"windows", b"chunk", b"per_block", b"gs_fuse",
            b"selective", b"jacobi_mask", b"strategy", b"warm_cap", b":", b"0", b"-1",
            b"18446744073709551615", b"1e308",
        ];
        fuzz_cases(corpus, dict, 12_000, 0x5EED, |case| {
            if let Ok(s) = std::str::from_utf8(case) {
                // String spellings: parse-or-reject, never panic.
                let _ = DecodePolicy::parse(s);
                let _ = InitPolicy::parse(s);
                // JSON spellings: anything jsonx accepts must either load as
                // a policy and round-trip, or reject with an error.
                if let Ok(v) = crate::jsonx::parse(s) {
                    if let Ok(p) = DecodePolicy::from_json(&v) {
                        assert_eq!(DecodePolicy::from_json(&p.to_json()).unwrap(), p);
                    }
                    if let Ok(ip) = InitPolicy::from_json(&v) {
                        assert_eq!(InitPolicy::from_json(&ip.to_json()).unwrap(), ip);
                    }
                }
            }
        });
    }
}
