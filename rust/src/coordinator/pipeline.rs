//! Stage-graph decode pipeline: inter-batch block overlap.
//!
//! SeJD's per-layer redundancy argument cuts the decode into `K`
//! independent **stages** — one flow block each, with disjoint artifacts —
//! yet the monolithic loop in `Sampler::decode_tokens` forces a serving
//! worker to run them strictly serially, one batch at a time. This module
//! restructures that loop into an explicit stage graph: a [`BlockStage`]
//! describes one stage's contract (decode position, flow block, policy
//! mode, output permutation), and a [`DecodePipeline`] walks batches
//! through the stages while keeping up to [`PipelineConfig::depth`] batches
//! in flight at *different* stages — batch B enters stage 0 while batch A
//! is in stage 1, because block `k` of A and block `k−1` of B touch
//! disjoint artifacts.
//!
//! ## Execution model
//!
//! The pipeline spawns [`PipelineConfig::stage_threads`] stage-executor
//! threads; each owns its **own backend** (device values are thread-pinned,
//! see the `runtime` docs) plus a per-bucket `SamplerSet`, and runs a
//! contiguous span of decode positions. Batches flow through bounded
//! per-stage queues (capacity 1 — a stage can hold at most one waiting
//! batch, so a slow stage backpressures its upstream immediately), and a
//! global depth gate bounds total in-flight batches, which bounds memory
//! and keeps tail latency honest.
//!
//! ## Device-value handoff
//!
//! *Within* a stage span, block outputs chain device→device exactly like
//! the monolithic loop — the span runs through `Sampler::decode_block_at`
//! over one backend, so nothing new crosses the host boundary. *Between*
//! stage threads the handoff must be host data (device handles are
//! `Rc`-pinned to the minting backend), so each span ends with one
//! documented forced sync. A single-threaded pipeline (`stage_threads = 1`)
//! therefore reproduces the monolithic residency map bit for bit: one
//! upload, K chained blocks, one final sync. With one thread per block the
//! per-stage sync cost is `K − 1` extra `[B, L, D]` round-trips per batch —
//! the price of overlap, paid only when overlap is enabled.
//!
//! Results are **bit-exact** with the monolithic path regardless of depth
//! or thread count: stages never share mutable state, every batch's prior
//! comes from its own seeded RNG stream, and host↔device crossings
//! preserve bits (`rust/tests/mock_backend.rs` pins the equivalence at
//! τ = 0; `benches/pipeline_overlap.rs` gates the throughput win in CI).
//!
//! ## Cross-stage z⁰ edge (speculative init under pipelining)
//!
//! Speculative init providers (`--init proj|warm|draft`, see
//! `coordinator::jacobi::InitStrategy`) add one more conceptual edge to the
//! stage graph: the z⁰ a block starts its fixed-point iteration from may
//! depend on state produced by an *earlier* stage. Device handles are
//! thread-pinned, so that state cannot ride the stage queue as a device
//! value — and syncing a speculative guess to host would break the
//! device-residency rule (speculation must never add host crossings). The
//! edge is therefore **receiver-materialized**:
//!
//! * **`proj`** — the projection input is exactly the handed-off tokens the
//!   receiving span uploads anyway, so the receiving stage re-derives z⁰ on
//!   its *own* backend (`Sampler::decode_block_at` resolves the provider
//!   per block). The edge carries the recipe, not the value: one upload
//!   (already paid by the handoff contract), zero extra syncs.
//! * **`warm`** — converged latents are keyed `(seed, position)` and decode
//!   positions are pinned to stages, so each stage thread's own
//!   `BufferPool` warm cache serves repeat-seed traffic for its span
//!   without anything crossing the edge. [`PipelineConfig::warm_cap`]
//!   bounds each stage's cache.
//! * **`draft`** — needs a full-sequence monolithic pass before refinement,
//!   which no single stage span can run; [`DecodePipeline::submit`] demotes
//!   it to `zeros` explicitly (documented, not silent) rather than letting
//!   the per-block resolver quietly ignore it.
//!
//! ## Multi-device placement
//!
//! With [`PipelineConfig::devices`] > 1 the stage graph is **sharded across
//! device ordinals**: contiguous stage spans map onto distinct ordinals via
//! [`device_placement`] (the same partition law as the spans themselves, so
//! K blocks stream across N devices with at most one device difference in
//! stage count). Each stage-executor thread hands its assigned ordinal to
//! the backend factory — the real-engine factory builds
//! `Engine::new_on(dir, ordinal)`, so the stage's executables and minted
//! buffers are pinned to that device and the per-ordinal aliasing guards
//! hold. Placement changes *where* a span computes, never *what* it
//! computes: the cross-thread handoff is host data either way (exactly one
//! documented sync per span boundary, `sjd_handoff_syncs`), so τ=0 decodes
//! stay bit-exact under every placement (`rust/tests/multidevice.rs` pins
//! this with per-ordinal mock ledgers).
//!
//! ## Metrics
//!
//! Per stage thread `t`: `sjd_stage_{t}_occupancy` (gauge, batches being
//! processed — 0/1 per pipeline, and its time-average is the stage's
//! utilization) and the shared `sjd_stage_wait` histogram (time a batch
//! sat in a stage queue before the stage picked it up — non-zero waits
//! mean the pipeline is genuinely overlapping). When several pipelines
//! share one registry (`serve --workers N --pipeline-depth ≥2` runs one
//! pipeline per worker), both metrics aggregate across them: stage `t`'s
//! occupancy reads `0..=N` and `sjd_stage_wait` pools every worker's
//! queue waits. Per device ordinal `d`: `sjd_device_{d}_busy` (gauge,
//! stages on that ordinal currently decoding — its time-average is the
//! device's utilization, the number the capacity bench exists to raise)
//! and the shared `sjd_handoff_syncs` counter (cross-span host handoffs).

use super::batcher::{Batcher, Slot, WORKER_FAILED_MSG};
use super::fault::{
    panic_msg, DeadlineCell, FaultPolicy, FaultTolerantBackend, Watchdog, WATCHDOG_FIRED_MSG,
};
use super::jacobi::InitStrategy;
use super::policy::{BlockDecode, DecodePolicy, OverloadGovernor};
use super::sampler::{covering_bucket, BlockTrace, SampleOptions, SampleOutput, SamplerSet};
use super::state::slot_composition_seed;
use crate::metrics::{Counter, Histogram, Registry};
use crate::runtime::{classify, Backend, FaultClass, HostTensor, Value};
use crate::tensor::Tensor;
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One stage of the decode stage graph: a single flow block with its decode
/// mode and in/out contract. Purely descriptive — execution is
/// `Sampler::decode_block_at` — used by `sjd policy show`, the `/policy`
/// endpoint and pipeline observability.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BlockStage {
    /// Decode position (0 = first block applied to noise).
    pub position: usize,
    /// Flow-order block index `k = K − 1 − position` — the index the
    /// stage's artifacts are keyed by.
    pub block: usize,
    /// Policy decode mode (before the sampler's per-bucket artifact
    /// degradation chain).
    pub mode: BlockDecode,
    /// Whether the stage output is token-reversed (`P_k`, odd `k`) before
    /// handoff to the next stage.
    pub reversed: bool,
}

/// The stage graph a policy induces over a `K`-block flow, in decode order.
pub fn stage_plan(policy: &DecodePolicy, blocks: usize) -> Vec<BlockStage> {
    (0..blocks)
        .map(|pos| {
            let block = blocks - 1 - pos;
            BlockStage {
                position: pos,
                block,
                mode: policy.block_mode(pos, blocks),
                reversed: block % 2 == 1,
            }
        })
        .collect()
}

/// Map `stages` stage indices onto `devices` device ordinals: contiguous,
/// as-even-as-possible groups (the same partition law as
/// [`super::jacobi::window_partition`], which it reuses), so adjacent
/// decode positions share a device and every cross-device edge is a span
/// boundary that was already paying the host handoff. `devices` clamps to
/// `[1, stages]`; entry `i` is stage `i`'s ordinal, non-decreasing from 0.
pub fn device_placement(stages: usize, devices: usize) -> Vec<usize> {
    let mut placement = vec![0usize; stages];
    for (ordinal, (off, len)) in
        super::jacobi::window_partition(stages, devices.max(1)).into_iter().enumerate()
    {
        for slot in placement.iter_mut().skip(off).take(len) {
            *slot = ordinal;
        }
    }
    placement
}

/// Pipeline shape knobs.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Maximum batches in flight across the whole pipeline (≥ 1). Depth 1
    /// is the monolithic serial decode expressed through the pipeline;
    /// depth ≥ 2 enables inter-batch block overlap.
    pub depth: usize,
    /// Stage-executor threads, each owning a backend and a contiguous span
    /// of decode positions; clamped to `[1, K]`, and `0` means one thread
    /// per block (maximum overlap).
    pub stage_threads: usize,
    /// Warm-start cache bound applied to every stage sampler's buffer pool
    /// (`--init warm:N`); `0` keeps the pool's built-in default. Each stage
    /// thread owns its own cache, so the effective pipeline-wide bound is
    /// `stage_threads × warm_cap` entries.
    pub warm_cap: usize,
    /// Device ordinals to shard the stage graph across (`serve --devices`).
    /// Contiguous stage spans map onto ordinals `0..devices` via
    /// [`device_placement`]; each stage's backend factory receives its
    /// stage's ordinal. `0` and `1` both mean single-device (every stage on
    /// ordinal 0 — the legacy layout); values above the stage count clamp
    /// down to it (a device without a stage would sit idle).
    pub devices: usize,
    /// Fault-tolerance policy: each stage's backend is wrapped in a
    /// [`FaultTolerantBackend`] (transient retry, per-artifact quarantine);
    /// the continuous path additionally budgets retries against the wave's
    /// earliest slot deadline and arms the hung-dispatch watchdog per span.
    pub fault: FaultPolicy,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            depth: 2,
            stage_threads: 0,
            warm_cap: 0,
            devices: 1,
            fault: FaultPolicy::default(),
        }
    }
}

/// What a completed batch resolves to: the per-sample images plus the same
/// [`SampleOutput`] a monolithic `sample_images` returns, or the decode
/// error message (`String`, like `batcher::SlotResult`, so every slot of a
/// failed batch can carry its own copy).
pub type PipelineResult = std::result::Result<(Vec<Tensor>, SampleOutput), String>;

/// Completion callback of one submitted batch.
pub type DoneFn = Box<dyn FnOnce(PipelineResult) + Send + 'static>;

/// One batch submitted to the pipeline.
pub struct PipelineJob {
    /// Per-slot request seeds, in batch-row order: stage 0 draws row `i`'s
    /// prior from `Pcg64::seed_stream(seeds[i], 1)` — the same stream a
    /// solo `b=1` decode of that request uses, so a slot's image is a pure
    /// function of its own seed, never of its batch position (see
    /// `Sampler::sample_prior_slots`). Stages route the batch to the
    /// smallest bucket covering `seeds.len()` exactly like a monolithic
    /// worker.
    pub seeds: Vec<u64>,
    pub opts: SampleOptions,
    /// Completion callback, invoked on the final stage's thread (keep it
    /// light — it runs on the decode path).
    pub done: DoneFn,
}

/// A batch moving through the stage graph.
struct InFlight {
    seeds: Vec<u64>,
    opts: SampleOptions,
    done: DoneFn,
    /// Host tokens between stage spans (`None` until stage 0 draws the
    /// prior). Cross-thread handoff is host data by contract.
    tokens: Option<HostTensor>,
    traces: Vec<BlockTrace>,
    decode_wall: Duration,
    /// Time spent waiting in stage queues *after* stage 0 started — the
    /// depth-≥2 interleaving cost, kept out of `other_wall` so that field
    /// retains its documented meaning.
    queued: Duration,
    /// When stage 0 started processing (anchor of `total_wall`).
    started: Option<Instant>,
    /// When the batch entered its current queue (stage-wait accounting).
    enqueued: Instant,
}

/// Bounded channel with blocking send — the per-stage queue + backpressure
/// primitive.
struct StageQueue<T> {
    inner: Mutex<StageQueueInner<T>>,
    cv: Condvar,
    cap: usize,
}

struct StageQueueInner<T> {
    q: VecDeque<T>,
    closed: bool,
}

impl<T> StageQueue<T> {
    fn new(cap: usize) -> Arc<Self> {
        Arc::new(StageQueue {
            inner: Mutex::new(StageQueueInner { q: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            cap: cap.max(1),
        })
    }

    /// Blocking send; a closed queue hands the item back so the caller can
    /// complete it with an error instead of silently dropping it.
    fn send(&self, item: T) -> std::result::Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        while g.q.len() >= self.cap && !g.closed {
            g = self.cv.wait(g).unwrap();
        }
        if g.closed {
            return Err(item);
        }
        g.q.push_back(item);
        self.cv.notify_all();
        Ok(())
    }

    /// Blocking receive; `None` once closed and drained.
    fn recv(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.q.pop_front() {
                self.cv.notify_all();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Non-blocking receive — the continuous path's straggler probe: a
    /// stage that just picked up a wave checks for another one already
    /// queued at the same boundary (hence at the same decode position) and
    /// merges it instead of decoding two padded fragments.
    fn try_recv(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        let item = g.q.pop_front();
        if item.is_some() {
            self.cv.notify_all();
        }
        item
    }

    fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

/// Counting gate bounding total in-flight batches (acquired on submit,
/// released at completion).
struct DepthGate {
    count: Mutex<usize>,
    cv: Condvar,
    depth: usize,
}

impl DepthGate {
    fn new(depth: usize) -> Arc<Self> {
        Arc::new(DepthGate { count: Mutex::new(0), cv: Condvar::new(), depth: depth.max(1) })
    }

    fn acquire(&self) {
        let mut c = self.count.lock().unwrap();
        while *c >= self.depth {
            c = self.cv.wait(c).unwrap();
        }
        *c += 1;
    }

    fn release(&self) {
        let mut c = self.count.lock().unwrap();
        *c -= 1;
        self.cv.notify_all();
    }

    fn current(&self) -> usize {
        *self.count.lock().unwrap()
    }
}

/// Running stage-graph pipeline (see the module docs).
pub struct DecodePipeline {
    entry: Arc<StageQueue<InFlight>>,
    gate: Arc<DepthGate>,
    threads: Vec<JoinHandle<()>>,
    /// Set by a stage that panicked or lost its device: the pipeline can no
    /// longer make progress and must be torn down + respawned (the feeding
    /// worker checks this and exits `DeviceLost`).
    lost: Arc<AtomicBool>,
    /// Bucket sizes the stage samplers serve, ascending.
    pub buckets: Vec<usize>,
    /// Flow blocks `K` (= number of stages in the graph).
    pub blocks: usize,
}

/// Everything one stage-executor thread needs besides its backend factory.
struct StageArgs {
    idx: usize,
    /// Decode positions `[lo, hi)` this stage runs.
    span: (usize, usize),
    model: String,
    buckets: Vec<usize>,
    rx: Arc<StageQueue<InFlight>>,
    tx: Option<Arc<StageQueue<InFlight>>>,
    gate: Arc<DepthGate>,
    registry: Registry,
    /// Device ordinal this stage is placed on ([`device_placement`]); handed
    /// to the backend factory and the `sjd_device_{d}_busy` gauge.
    device: usize,
    /// Warm-start cache bound for this stage's samplers (0 = default).
    warm_cap: usize,
    /// Retry/quarantine policy for this stage's backend wrapper.
    fault: FaultPolicy,
    /// Shared lost-pipeline flag (see [`DecodePipeline::lost`]).
    lost: Arc<AtomicBool>,
    ready: std::sync::mpsc::Sender<Result<Vec<usize>>>,
}

impl DecodePipeline {
    /// Spawn the stage-executor threads. `factory` runs inside each stage
    /// thread (backends may be thread-pinned) with the stage's **device
    /// ordinal** from [`device_placement`] as its argument — the real-engine
    /// factory opens `Engine::new_on(dir, ordinal)`, mocks key per-ordinal
    /// ledgers off it, and single-device factories may ignore it (every
    /// ordinal is 0 when `cfg.devices ≤ 1`). It is also invoked once on the
    /// calling thread, with ordinal 0, to discover the model geometry; like
    /// `Router::start_with`, every stage validates its backend + samplers
    /// before this returns (fail-fast on bad artifacts).
    pub fn start<B, F>(
        model: &str,
        buckets: &[usize],
        cfg: PipelineConfig,
        registry: Registry,
        factory: F,
    ) -> Result<Self>
    where
        B: Backend,
        F: Fn(usize) -> Result<B> + Send + Clone + 'static,
    {
        // Geometry probe, dropped immediately — stage threads build their
        // own thread-pinned backends. The spans and queues must be sized
        // before any stage thread exists, so K cannot ride the readiness
        // channel; the extra backend is cheap because `Engine` construction
        // only parses the manifest (artifact compilation is lazy, and the
        // probe never calls anything).
        let blocks = factory(0)?.model_meta(model)?.blocks;
        let n_threads = if cfg.stage_threads == 0 {
            blocks
        } else {
            cfg.stage_threads.clamp(1, blocks)
        };
        // Contiguous, as-even-as-possible spans of decode positions — the
        // same partition law the GS windows use.
        let spans: Vec<(usize, usize)> = super::jacobi::window_partition(blocks, n_threads)
            .into_iter()
            .map(|(off, len)| (off, off + len))
            .collect();
        let placement = device_placement(spans.len(), cfg.devices);
        let queues: Vec<Arc<StageQueue<InFlight>>> =
            spans.iter().map(|_| StageQueue::new(1)).collect();
        let gate = DepthGate::new(cfg.depth);
        let lost = Arc::new(AtomicBool::new(false));
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<Vec<usize>>>();

        let mut threads = Vec::with_capacity(spans.len());
        for (idx, &span) in spans.iter().enumerate() {
            let args = StageArgs {
                idx,
                span,
                model: model.to_string(),
                buckets: buckets.to_vec(),
                rx: queues[idx].clone(),
                tx: queues.get(idx + 1).cloned(),
                gate: gate.clone(),
                registry: registry.clone(),
                device: placement[idx],
                warm_cap: cfg.warm_cap,
                fault: cfg.fault.clone(),
                lost: lost.clone(),
                ready: ready_tx.clone(),
            };
            let factory = factory.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("sjd-stage-{idx}"))
                    .spawn(move || stage_main(args, factory))
                    .expect("spawn stage thread"),
            );
        }
        drop(ready_tx);
        // Collect every stage's readiness before returning: on any failure,
        // close the queues and join the healthy stages too, so a failed
        // startup never leaves threads (each pinning a backend) blocked on
        // queues nobody will feed.
        let mut bucket_set = Vec::new();
        let mut startup_err = None;
        for _ in &spans {
            match ready_rx.recv().expect("stage startup signal") {
                Ok(buckets) => bucket_set = buckets,
                Err(e) => startup_err = Some(e),
            }
        }
        if let Some(e) = startup_err {
            for q in &queues {
                q.close();
            }
            for t in threads.drain(..) {
                let _ = t.join();
            }
            return Err(e);
        }
        Ok(DecodePipeline {
            entry: queues[0].clone(),
            gate,
            threads,
            lost,
            buckets: bucket_set,
            blocks,
        })
    }

    /// Whether a stage panicked or lost its device: the pipeline must be
    /// shut down and respawned (its queues are already closing; in-flight
    /// batches resolve `Err` on their way out).
    pub fn lost(&self) -> bool {
        self.lost.load(Ordering::SeqCst)
    }

    /// Submit a batch, blocking while [`PipelineConfig::depth`] batches are
    /// already in flight (backpressure toward the batcher queue). A
    /// shut-down pipeline hands the job back so the caller can complete its
    /// slots.
    pub fn submit(&self, job: PipelineJob) -> std::result::Result<(), PipelineJob> {
        self.gate.acquire();
        // Draft-then-refine needs a full-sequence pass before refinement —
        // no single stage span can run it (see "Cross-stage z⁰ edge" in the
        // module docs). Demote to zeros here, explicitly, so traces report
        // what actually ran instead of the per-block resolver quietly
        // ignoring the strategy.
        let mut opts = job.opts;
        if opts.jacobi.init == InitStrategy::Draft {
            opts.jacobi.init = InitStrategy::Zeros;
        }
        let item = InFlight {
            seeds: job.seeds,
            opts,
            done: job.done,
            tokens: None,
            traces: Vec::new(),
            decode_wall: Duration::ZERO,
            queued: Duration::ZERO,
            started: None,
            enqueued: Instant::now(),
        };
        match self.entry.send(item) {
            Ok(()) => Ok(()),
            Err(item) => {
                self.gate.release();
                Err(PipelineJob { seeds: item.seeds, opts: item.opts, done: item.done })
            }
        }
    }

    /// Batches currently in flight (submitted, not yet completed).
    pub fn in_flight(&self) -> usize {
        self.gate.current()
    }

    /// Close the entry queue, drain every in-flight batch to completion,
    /// and join the stage threads.
    pub fn shutdown(mut self) {
        self.entry.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// One stage-executor thread: own backend + samplers, a contiguous span of
/// decode positions, and the stage queue protocol.
fn stage_main<B, F>(args: StageArgs, factory: F)
where
    B: Backend,
    F: Fn(usize) -> Result<B>,
{
    let StageArgs {
        idx,
        span,
        model,
        buckets,
        rx,
        tx,
        gate,
        registry,
        device,
        warm_cap,
        fault,
        lost,
        ready,
    } = args;
    // Stage backends get the same fault-tolerant wrapper as monolithic
    // workers: transient retries and per-artifact quarantine (the stage's
    // samplers consult the wrapper's `has_artifact` live per block decode).
    // The factory receives this stage's device ordinal (the placement seam).
    let engine = match factory(device) {
        Ok(e) => FaultTolerantBackend::new(e, fault.clone(), &registry),
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let set = match SamplerSet::new(&engine, &model, &buckets) {
        Ok(s) => s,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    set.set_warm_cap(warm_cap);
    let _ = ready.send(Ok(set.buckets()));

    let occupancy = registry.gauge(&format!("sjd_stage_{idx}_occupancy"));
    let device_busy = registry.gauge(&format!("sjd_device_{device}_busy"));
    let stage_wait = registry.histogram("sjd_stage_wait");
    let m_handoffs = registry.counter("sjd_handoff_syncs");
    let m_panics = registry.counter("sjd_worker_panics");

    while let Some(mut item) = rx.recv() {
        let waited = item.enqueued.elapsed();
        stage_wait.record_duration(waited);
        // Waits before stage 0 are ordinary queueing (not yet started);
        // waits between stages are the pipelining cost `finish` subtracts.
        if item.started.is_some() {
            item.queued += waited;
        }
        occupancy.add(1);
        device_busy.add(1);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_span(&set, span, &mut item)
        }));
        occupancy.add(-1);
        device_busy.add(-1);
        match outcome {
            Err(p) => {
                // A panic mid-decode means the engine state is suspect:
                // fail the batch, mark the pipeline lost, and exit so the
                // feeding worker tears the whole pipeline down for respawn.
                m_panics.inc();
                log::error!("stage {idx} panicked mid-decode: {}", panic_msg(&p));
                (item.done)(Err(format!("{WORKER_FAILED_MSG}: stage {idx} panicked")));
                gate.release();
                lost.store(true, Ordering::SeqCst);
                rx.close();
                break;
            }
            Ok(Err(fail)) => {
                // Fail the batch here; downstream stages never see it.
                (item.done)(Err(fail.msg));
                gate.release();
                if fail.device_lost {
                    lost.store(true, Ordering::SeqCst);
                    rx.close();
                    break;
                }
            }
            Ok(Ok(())) => match &tx {
                Some(tx) => {
                    // The span just ended with its one documented host sync
                    // and the batch crosses a span boundary: count it.
                    m_handoffs.inc();
                    item.enqueued = Instant::now();
                    if let Err(item) = tx.send(item) {
                        // Downstream closed mid-shutdown: complete the batch
                        // so its slots cannot hang, and free its slot.
                        (item.done)(Err("pipeline shut down mid-decode".into()));
                        gate.release();
                    }
                }
                None => finish(&set, item, &gate),
            },
        }
    }
    // Cascade the close downstream so later stages drain and exit too.
    if let Some(tx) = &tx {
        tx.close();
    }
}

/// A failed span: the error message for the batch's slots, plus whether
/// the failure was `DeviceLost`-classified — the stage must then shut down
/// so the whole pipeline is respawned with fresh engines.
struct SpanFail {
    msg: String,
    device_lost: bool,
}

impl SpanFail {
    fn new(context: &str, e: &anyhow::Error) -> Self {
        SpanFail {
            msg: format!("{context}: {e:#}"),
            device_lost: classify(e) == FaultClass::DeviceLost,
        }
    }
}

/// Run one span of decode positions over one batch. Stage 0 draws each
/// slot's prior from that slot's own seed stream (per-slot RNG — batch
/// position can never change a request's image); every span chains
/// device-resident values internally and syncs to host once at its end
/// (the cross-thread handoff contract).
fn run_span<B: Backend>(
    set: &SamplerSet<'_, B>,
    (lo, hi): (usize, usize),
    item: &mut InFlight,
) -> std::result::Result<(), SpanFail> {
    let sampler = set.select(item.seeds.len());
    if lo == 0 {
        item.started = Some(Instant::now());
        item.tokens = Some(sampler.sample_prior_slots(&item.seeds));
    }
    let mut z = Value::Host(item.tokens.take().expect("pipeline handoff carries tokens"));
    for pos in lo..hi {
        let (z_next, trace) = sampler
            .decode_block_at(pos, &z, &item.opts)
            .map_err(|e| SpanFail::new(&format!("decode failed at position {pos}"), &e))?;
        item.decode_wall += trace.wall;
        item.traces.push(trace);
        z = z_next;
    }
    let host = sampler
        .engine()
        .to_host(z)
        .map_err(|e| SpanFail::new("stage handoff sync failed", &e))?;
    item.tokens = Some(host);
    Ok(())
}

/// Final-stage completion: assemble the [`SampleOutput`], unpatchify, and
/// resolve the job.
///
/// `total_wall` is the true in-pipeline latency (stage-0 start →
/// completion, inter-stage queue waits included — what the overlap bench's
/// p99 gate measures); `other_wall` excludes those waits so it keeps its
/// documented meaning (prior draw, permutations, handoff syncs).
fn finish<B: Backend>(set: &SamplerSet<'_, B>, mut item: InFlight, gate: &Arc<DepthGate>) {
    let sampler = set.select(item.seeds.len());
    let tokens = item.tokens.take().expect("completed batch has tokens");
    let total_wall = item.started.map(|s| s.elapsed()).unwrap_or_default();
    let busy = total_wall.saturating_sub(item.queued);
    let out = SampleOutput {
        tokens,
        traces: std::mem::take(&mut item.traces),
        total_wall,
        other_wall: busy.saturating_sub(item.decode_wall),
    };
    let done = item.done;
    match sampler.unpatchify(&out.tokens) {
        Ok(images) => done(Ok((images, out))),
        Err(e) => done(Err(format!("unpatchify failed: {e:#}"))),
    }
    gate.release();
}

// ---------------------------------------------------------------------------
// Continuous batching: waves that change membership at block boundaries.
// ---------------------------------------------------------------------------

/// One request riding a continuous wave: the batcher slot plus its own
/// per-block trace history (traces survive remap/migration because they
/// travel with the slot, not with the wave).
struct LiveSlot {
    slot: Slot,
    traces: Vec<BlockTrace>,
}

/// A batch whose membership is open at every block boundary: row `i` of
/// `tokens` is `slots[i]`'s latent; rows past `slots.len()` (up to
/// `bucket`) are padding. Formed at stage 0 from the batcher queue, topped
/// up there by the non-blocking refill drain, swept/compacted/migrated at
/// every stage entry, and resolved per-slot at the final stage.
struct Wave {
    slots: Vec<LiveSlot>,
    /// Host tokens `[bucket, L, D]` between stage spans (the same
    /// cross-thread handoff contract as [`InFlight::tokens`]).
    tokens: HostTensor,
    /// The covering bucket `tokens` is currently shaped for.
    bucket: usize,
    /// Per-wave decode options; `opts.seed` is the slot-composition hash
    /// ([`slot_composition_seed`]), recomputed after every membership
    /// change so warm-cache keys can never alias a different composition.
    opts: SampleOptions,
    /// When the wave entered its current stage queue (stage-wait metric).
    enqueued: Instant,
}

/// Continuous-batching metric handles, resolved once per stage thread.
struct ContMetrics {
    refills: Arc<Counter>,
    migrations: Arc<Counter>,
    merges: Arc<Counter>,
    cancelled: Arc<Counter>,
    /// Slots resolved 504 at a block boundary (deadline passed mid-flight).
    /// Same counter the batcher's queued-expiry purge increments — one
    /// `sjd_deadline_expired` series covers every enforcement point.
    deadline_expired: Arc<Counter>,
    padded: Arc<Counter>,
    padded_blocks: Arc<Counter>,
    images: Arc<Counter>,
    batches: Arc<Counter>,
    errors: Arc<Counter>,
    latency: Arc<Histogram>,
    queue_wait: Arc<Histogram>,
    batch_fill: Arc<Histogram>,
    block_iters: Arc<Histogram>,
    host_syncs: Arc<Histogram>,
    stage_wait: Arc<Histogram>,
    /// Cross-span host handoffs (one per wave per span boundary — the same
    /// `sjd_handoff_syncs` series the non-continuous pipeline charges).
    handoffs: Arc<Counter>,
}

impl ContMetrics {
    fn new(registry: &Registry) -> Self {
        ContMetrics {
            refills: registry.counter("sjd_batch_refills"),
            migrations: registry.counter("sjd_bucket_migrations"),
            merges: registry.counter("sjd_straggler_merges"),
            cancelled: registry.counter("sjd_slots_cancelled"),
            deadline_expired: registry.counter("sjd_deadline_expired"),
            padded: registry.counter("sjd_padded_slots"),
            padded_blocks: registry.counter("sjd_padded_slot_blocks"),
            images: registry.counter("sjd_images_generated"),
            batches: registry.counter("sjd_batches_processed"),
            errors: registry.counter("sjd_worker_errors"),
            latency: registry.histogram("sjd_request_latency"),
            queue_wait: registry.histogram("sjd_queue_wait"),
            batch_fill: registry.histogram("sjd_batch_fill"),
            block_iters: registry.histogram("sjd_block_iters"),
            host_syncs: registry.histogram("sjd_host_syncs"),
            stage_wait: registry.histogram("sjd_stage_wait"),
            handoffs: registry.counter("sjd_handoff_syncs"),
        }
    }
}

/// Stage-graph pipeline with **continuous batching**: requests enter and
/// exit a decode at block boundaries instead of riding one fixed batch end
/// to end.
///
/// Differences from [`DecodePipeline`]:
///
/// * **Stage 0 owns the batcher.** There is no submit path and no depth
///   gate — stage 0 pulls a batch with `Batcher::next_batch`, then tops it
///   up to the largest bucket with the non-blocking
///   [`Batcher::take_upto`] drain (`sjd_batch_refills`), so a request
///   arriving while a wave forms rides *this* wave instead of waiting a
///   full pipeline traversal. In-flight depth is bounded by the stage
///   queues (capacity [`CONT_QUEUE_CAP`] each).
/// * **Membership is per-slot, not per-batch.** At every stage entry the
///   wave sweeps out cancelled slots (`sjd_slots_cancelled`, each
///   completed with an error so its waiter never hangs), compacts the
///   survivors' rows with the slot-remap gather
///   ([`super::sampler::Sampler::gather_slots_v`], the device-side
///   `{m}_slot_gather_b{B}` artifact when lowered), and **migrates** to
///   the smaller covering bucket when one exists
///   (`sjd_bucket_migrations`) — a shrinking wave stops paying the big
///   bucket's padded-row decode cost mid-flight.
/// * **Stragglers merge instead of padding.** A stage that picks up a
///   wave probes its queue for another wave already parked at the same
///   boundary (necessarily at the same decode position — stages are
///   position-pinned) and adopts its slots while the combined wave fits
///   the largest bucket (`sjd_straggler_merges`), so two half-empty waves
///   decode as one fuller one.
/// * **Completion is per-slot.** The final stage resolves each slot's own
///   completion channel with its own image; `sjd_request_latency` is
///   per-slot, submit → image.
///
/// τ=0 bit-exactness survives all of it: each slot's prior comes from its
/// own seed stream ([`super::sampler::Sampler::sample_prior_slots`]), the
/// per-block fixed point is independent of the iterate's starting point
/// and of padding rows (Prop 3.2), and the remap gather only permutes
/// whole rows — so a request's output equals its solo serial decode no
/// matter which waves it rode through (`rust/tests/continuous.rs` pins
/// this over randomized join/leave/migrate schedules).
pub struct ContinuousPipeline {
    threads: Vec<JoinHandle<()>>,
    /// Set by a stage that panicked, lost its device, or hung past the
    /// watchdog: stages cascade their queue closes and exit, `join`
    /// returns, and the supervising worker respawns the whole pipeline.
    lost: Arc<AtomicBool>,
    /// Shared hung-dispatch monitor (one thread per pipeline), armed by
    /// every stage around its decode span; `None` when the policy disables
    /// the watchdog.
    dog: Option<Arc<Watchdog>>,
    /// Bucket sizes the stage samplers serve, ascending.
    pub buckets: Vec<usize>,
    /// Flow blocks `K` (= number of stages in the graph).
    pub blocks: usize,
}

/// Per-stage queue capacity of the continuous pipeline: 2, so a stage can
/// hold a parked wave *and* still have one arriving — the straggler-merge
/// window — while keeping total in-flight waves (and therefore memory)
/// bounded at `O(stages)`.
const CONT_QUEUE_CAP: usize = 2;

/// Everything one continuous stage-executor thread needs besides its
/// backend factory.
struct ContStageArgs {
    idx: usize,
    /// Decode positions `[lo, hi)` this stage runs.
    span: (usize, usize),
    model: String,
    buckets: Vec<usize>,
    /// Stage 0 pulls from the batcher; later stages from their queue.
    batcher: Option<Batcher>,
    rx: Option<Arc<StageQueue<Wave>>>,
    tx: Option<Arc<StageQueue<Wave>>>,
    registry: Registry,
    /// Base decode options; each wave clones them and overrides `seed`
    /// with its composition hash.
    options: SampleOptions,
    /// Device ordinal this stage is placed on ([`device_placement`]).
    device: usize,
    warm_cap: usize,
    /// Quality-elastic overload governor (`serve --elastic`): stage 0 feeds
    /// it queue depth and applies its degradation ladder to each freshly
    /// formed wave; the final stage feeds it per-slot completion latency.
    governor: Option<Arc<OverloadGovernor>>,
    /// Retry/quarantine/watchdog policy for this stage's backend wrapper.
    fault: FaultPolicy,
    /// Shared lost-pipeline flag (see [`ContinuousPipeline::lost_flag`]).
    lost: Arc<AtomicBool>,
    /// Shared hung-dispatch monitor (`None` = watchdog disabled).
    dog: Option<Arc<Watchdog>>,
    ready: std::sync::mpsc::Sender<Result<Vec<usize>>>,
}

impl ContinuousPipeline {
    /// Spawn the continuous stage threads. Same factory/readiness contract
    /// as [`DecodePipeline::start`]; `batcher` is the shared request queue
    /// stage 0 pulls and refills from. The pipeline runs until the batcher
    /// is closed and drained, then shuts itself down stage by stage —
    /// every slot accepted before close still resolves.
    ///
    /// [`PipelineConfig::depth`] is ignored: in-flight depth is the stage
    /// count times [`CONT_QUEUE_CAP`], bounded by construction.
    pub fn start<B, F>(
        model: &str,
        buckets: &[usize],
        cfg: PipelineConfig,
        registry: Registry,
        batcher: Batcher,
        options: SampleOptions,
        factory: F,
    ) -> Result<Self>
    where
        B: Backend,
        F: Fn(usize) -> Result<B> + Send + Clone + 'static,
    {
        Self::start_with_governor(model, buckets, cfg, registry, batcher, options, None, factory)
    }

    /// [`Self::start`] with an optional [`OverloadGovernor`]: stage 0
    /// observes queue depth and rewrites wave options through the
    /// degradation ladder at formation; the final stage feeds completion
    /// latencies back. With the governor at level 0 (or absent) the applied
    /// options are the configured ones — bit-exact at τ=0.
    #[allow(clippy::too_many_arguments)]
    pub fn start_with_governor<B, F>(
        model: &str,
        buckets: &[usize],
        cfg: PipelineConfig,
        registry: Registry,
        batcher: Batcher,
        options: SampleOptions,
        governor: Option<Arc<OverloadGovernor>>,
        factory: F,
    ) -> Result<Self>
    where
        B: Backend,
        F: Fn(usize) -> Result<B> + Send + Clone + 'static,
    {
        let blocks = factory(0)?.model_meta(model)?.blocks;
        let n_threads = if cfg.stage_threads == 0 {
            blocks
        } else {
            cfg.stage_threads.clamp(1, blocks)
        };
        let spans: Vec<(usize, usize)> = super::jacobi::window_partition(blocks, n_threads)
            .into_iter()
            .map(|(off, len)| (off, off + len))
            .collect();
        let placement = device_placement(spans.len(), cfg.devices);
        // Queue i feeds stage i (stage 0 has none — it pulls the batcher).
        let queues: Vec<Arc<StageQueue<Wave>>> =
            (1..spans.len()).map(|_| StageQueue::new(CONT_QUEUE_CAP)).collect();
        let lost = Arc::new(AtomicBool::new(false));
        let dog = cfg.fault.watchdog.map(|_| Watchdog::new(&registry));
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<Vec<usize>>>();

        let mut threads = Vec::with_capacity(spans.len());
        for (idx, &span) in spans.iter().enumerate() {
            let args = ContStageArgs {
                idx,
                span,
                model: model.to_string(),
                buckets: buckets.to_vec(),
                batcher: if idx == 0 { Some(batcher.clone()) } else { None },
                rx: if idx == 0 { None } else { Some(queues[idx - 1].clone()) },
                tx: queues.get(idx).cloned(),
                registry: registry.clone(),
                options: options.clone(),
                device: placement[idx],
                warm_cap: cfg.warm_cap,
                governor: governor.clone(),
                fault: cfg.fault.clone(),
                lost: lost.clone(),
                dog: dog.clone(),
                ready: ready_tx.clone(),
            };
            let factory = factory.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("sjd-cont-stage-{idx}"))
                    .spawn(move || cont_stage_main(args, factory))
                    .expect("spawn continuous stage thread"),
            );
        }
        drop(ready_tx);
        let mut bucket_set = Vec::new();
        let mut startup_err = None;
        for _ in &spans {
            match ready_rx.recv().expect("continuous stage startup signal") {
                Ok(buckets) => bucket_set = buckets,
                Err(e) => startup_err = Some(e),
            }
        }
        if let Some(e) = startup_err {
            // Unblock stage 0 (parked on the batcher) and the downstream
            // queues, then join everything — a failed startup never leaves
            // a thread pinning a backend behind.
            batcher.close();
            for q in &queues {
                q.close();
            }
            for t in threads.drain(..) {
                let _ = t.join();
            }
            if let Some(d) = &dog {
                d.shutdown();
            }
            return Err(e);
        }
        Ok(ContinuousPipeline { threads, lost, dog, buckets: bucket_set, blocks })
    }

    /// Shared lost-pipeline flag, readable after [`Self::join`] consumed the
    /// pipeline: `true` means a stage panicked, lost its device, or hung
    /// past the watchdog, and the supervising worker must respawn.
    pub fn lost_flag(&self) -> Arc<AtomicBool> {
        self.lost.clone()
    }

    /// Wait for the pipeline to drain and exit. That happens when the
    /// batcher is closed (stage 0 runs until `next_batch` returns `None`)
    /// — or, with the batcher still open, when a stage was lost and the
    /// queue closes cascaded (check [`Self::lost_flag`]).
    pub fn join(mut self) {
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        if let Some(d) = &self.dog {
            d.shutdown();
        }
    }
}

/// Per-stage fault context of the continuous path: the backend wrapper's
/// deadline cell, the shared watchdog + lost flag, and panic accounting.
struct StageFaults {
    idx: usize,
    deadline: DeadlineCell,
    dog: Option<Arc<Watchdog>>,
    timeout: Option<Duration>,
    lost: Arc<AtomicBool>,
    m_panics: Arc<Counter>,
}

/// One continuous stage-executor thread (see [`ContinuousPipeline`]).
fn cont_stage_main<B, F>(args: ContStageArgs, factory: F)
where
    B: Backend,
    F: Fn(usize) -> Result<B>,
{
    let ContStageArgs {
        idx,
        span,
        model,
        buckets,
        batcher,
        rx,
        tx,
        registry,
        options,
        device,
        warm_cap,
        governor,
        fault,
        lost,
        dog,
        ready,
    } = args;
    // Same fault-tolerant wrapper as monolithic workers: transient retry,
    // per-artifact quarantine (live `has_artifact` reroute), deadline-
    // budgeted backoff through the cell below. The factory receives this
    // stage's device ordinal (the placement seam).
    let engine = match factory(device) {
        Ok(e) => FaultTolerantBackend::new(e, fault.clone(), &registry),
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let set = match SamplerSet::new(&engine, &model, &buckets) {
        Ok(s) => s,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    set.set_warm_cap(warm_cap);
    let _ = ready.send(Ok(set.buckets()));

    let m = ContMetrics::new(&registry);
    let occupancy = registry.gauge(&format!("sjd_stage_{idx}_occupancy"));
    let device_busy = registry.gauge(&format!("sjd_device_{device}_busy"));
    let faults = StageFaults {
        idx,
        deadline: engine.deadline_cell(),
        dog,
        timeout: fault.watchdog,
        lost,
        m_panics: registry.counter("sjd_worker_panics"),
    };

    if let Some(batcher) = batcher {
        // Stage 0: form waves from the batcher, refill, decode, forward.
        while let Some(batch) = batcher.next_batch() {
            // A lost pipeline cannot decode this batch: fail it fast (the
            // respawned pipeline serves whatever arrives next) and exit so
            // `join` returns and the supervisor respawns everything.
            if faults.lost.load(Ordering::SeqCst) {
                for s in batch.slots {
                    s.done.put_once(Err(format!("{WORKER_FAILED_MSG}: pipeline stage lost")));
                }
                break;
            }
            let mut slots = batch.slots;
            let room = set.max_bucket().saturating_sub(slots.len());
            let extra = batcher.take_upto(room);
            m.refills.add(extra.len() as u64);
            slots.extend(extra);
            // Pressure sample at wave cadence: what is still queued after
            // this wave drained everything it could carry.
            if let Some(gov) = &governor {
                gov.observe(batcher.queued(), None);
            }
            let Some(mut wave) = form_wave(&set, slots, &options, governor.as_deref(), &m) else {
                continue; // everything was already cancelled or expired
            };
            occupancy.add(1);
            device_busy.add(1);
            let outcome = cont_decode_guarded(&set, span, &mut wave, &m, &faults);
            occupancy.add(-1);
            device_busy.add(-1);
            match outcome {
                Ok(()) => forward_or_finish(&set, span, wave, &tx, &governor, &m),
                Err((msg, lost_now)) => {
                    fail_wave(wave, &msg, &m);
                    if lost_now {
                        break;
                    }
                }
            }
            // A downstream stage was lost while this wave was in flight:
            // exit now instead of waiting for the next batch to notice.
            if faults.lost.load(Ordering::SeqCst) {
                break;
            }
        }
        if let Some(tx) = &tx {
            tx.close();
        }
        return;
    }

    let rx = rx.expect("non-zero continuous stage has an input queue");
    'recv: while let Some(mut wave) = rx.recv() {
        m.stage_wait.record_duration(wave.enqueued.elapsed());
        // Straggler merge: adopt waves already parked at this boundary
        // (same decode position by construction) while the union fits the
        // largest bucket — two half-empty waves decode as one fuller one.
        while let Some(extra) = rx.try_recv() {
            if wave.slots.len() + extra.slots.len() > set.max_bucket() {
                // Doesn't fit: hand it back? The queue is FIFO and we're
                // its only consumer — decode it next iteration instead.
                let requeue = extra;
                if !process_wave(&set, span, requeue, &tx, &governor, &m, &occupancy, &device_busy, &faults)
                {
                    rx.close();
                    break 'recv;
                }
                break;
            }
            m.merges.inc();
            merge_waves(&set, &mut wave, extra);
        }
        if !process_wave(&set, span, wave, &tx, &governor, &m, &occupancy, &device_busy, &faults) {
            rx.close();
            break 'recv;
        }
    }
    if let Some(tx) = &tx {
        tx.close();
    }
}

/// Sweep + remap + decode + forward one wave through this stage's span.
/// Returns `false` when the stage was lost (panic, device loss, or a fired
/// watchdog) and must shut down for respawn.
#[allow(clippy::too_many_arguments)]
fn process_wave<B: Backend>(
    set: &SamplerSet<'_, B>,
    span: (usize, usize),
    mut wave: Wave,
    tx: &Option<Arc<StageQueue<Wave>>>,
    governor: &Option<Arc<OverloadGovernor>>,
    m: &ContMetrics,
    occupancy: &Arc<crate::metrics::Gauge>,
    device_busy: &Arc<crate::metrics::Gauge>,
    faults: &StageFaults,
) -> bool {
    match sweep_and_remap(set, &mut wave, m) {
        Err(msg) => {
            fail_wave(wave, &msg, m);
            return true;
        }
        Ok(false) => return true, // every slot left; nothing to decode
        Ok(true) => {}
    }
    occupancy.add(1);
    device_busy.add(1);
    let outcome = cont_decode_guarded(set, span, &mut wave, m, faults);
    occupancy.add(-1);
    device_busy.add(-1);
    match outcome {
        Ok(()) => {
            forward_or_finish(set, span, wave, tx, governor, m);
            true
        }
        Err((msg, lost_now)) => {
            fail_wave(wave, &msg, m);
            !lost_now
        }
    }
}

/// Decode one span under the stage's fault context: publish the wave's
/// earliest slot deadline (the retry layer budgets backoff against it), arm
/// the hung-dispatch watchdog with the wave's completion channels, and
/// catch panics. `Err((msg, lost))` fails the wave; `lost` additionally
/// marks the pipeline lost so the worker supervisor respawns it.
fn cont_decode_guarded<B: Backend>(
    set: &SamplerSet<'_, B>,
    span: (usize, usize),
    wave: &mut Wave,
    m: &ContMetrics,
    f: &StageFaults,
) -> std::result::Result<(), (String, bool)> {
    f.deadline.set(wave.slots.iter().filter_map(|ls| ls.slot.deadline).min());
    let guard = f.dog.as_ref().zip(f.timeout).map(|(d, t)| {
        d.guard(t, wave.slots.iter().map(|ls| ls.slot.done.clone()).collect())
    });
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        cont_decode_span(set, span, wave, m)
    }));
    f.deadline.clear();
    let fired = guard.as_ref().is_some_and(|g| g.fired());
    drop(guard);
    match outcome {
        Err(p) => {
            f.m_panics.inc();
            let msg = panic_msg(&p);
            log::error!("continuous stage {} panicked mid-decode: {msg}", f.idx);
            f.lost.store(true, Ordering::SeqCst);
            Err((format!("{WORKER_FAILED_MSG}: stage {} panicked", f.idx), true))
        }
        Ok(_) if fired => {
            // The monitor already resolved the wave's slots; a result this
            // late is untrustworthy — replace the engine.
            log::error!("continuous stage {} dispatch hung past the watchdog", f.idx);
            f.lost.store(true, Ordering::SeqCst);
            Err((format!("{WATCHDOG_FIRED_MSG} (dispatch hung)"), true))
        }
        Ok(Err(fail)) => {
            if fail.device_lost {
                f.lost.store(true, Ordering::SeqCst);
            }
            Err((fail.msg, fail.device_lost))
        }
        Ok(Ok(())) => Ok(()),
    }
}

/// Stage-0 wave formation: sweep slots already cancelled or expired in the
/// queue, record queue-wait/fill/padding, apply the overload governor's
/// current ladder level to the wave's decode options, and draw each slot's
/// prior from its own seed stream.
fn form_wave<B: Backend>(
    set: &SamplerSet<'_, B>,
    slots: Vec<Slot>,
    options: &SampleOptions,
    governor: Option<&OverloadGovernor>,
    m: &ContMetrics,
) -> Option<Wave> {
    let mut live = Vec::with_capacity(slots.len());
    for s in slots {
        if s.cancelled() {
            m.cancelled.inc();
            s.done.put_once(Err("request cancelled (client disconnected)".into()));
        } else if s.expired() {
            m.deadline_expired.inc();
            s.resolve_expired("wave formation");
        } else {
            live.push(s);
        }
    }
    if live.is_empty() {
        return None;
    }
    for s in &live {
        m.queue_wait.record_duration(s.enqueued.elapsed());
    }
    let bucket = covering_bucket(&set.buckets(), live.len()).expect("non-empty bucket set");
    let sampler = set.select(live.len());
    m.batch_fill.record(live.len() as u64);
    m.padded.add((bucket - live.len().min(bucket)) as u64);
    let seeds: Vec<u64> = live.iter().map(|s| s.seed).collect();
    // Ladder level is sampled once per wave, at formation — every stage the
    // wave traverses decodes with the same options, so a mid-flight level
    // change can never split one request's decode across two τ values.
    let mut opts = match governor {
        Some(gov) => gov.apply(options),
        None => options.clone(),
    };
    opts.seed = slot_composition_seed(&seeds);
    let tokens = sampler.sample_prior_slots(&seeds);
    Some(Wave {
        slots: live.into_iter().map(|slot| LiveSlot { slot, traces: Vec::new() }).collect(),
        tokens,
        bucket,
        opts,
        enqueued: Instant::now(),
    })
}

/// Concatenate `extra`'s live rows onto `wave` (same decode position by
/// construction), re-bucket, and recompute the composition seed. Slots
/// carry their traces with them.
fn merge_waves<B: Backend>(set: &SamplerSet<'_, B>, wave: &mut Wave, extra: Wave) {
    let (na, nb) = (wave.slots.len(), extra.slots.len());
    let total = na + nb;
    let bucket = covering_bucket(&set.buckets(), total).expect("non-empty bucket set");
    let shape = wave.tokens.shape().to_vec();
    let (l, d) = (shape[1], shape[2]);
    let row = l * d;
    let mut data = vec![0.0f32; bucket * row];
    let a = wave.tokens.as_f32().expect("wave tokens are f32");
    let b = extra.tokens.as_f32().expect("wave tokens are f32");
    data[..na * row].copy_from_slice(&a[..na * row]);
    data[na * row..total * row].copy_from_slice(&b[..nb * row]);
    wave.tokens = HostTensor::f32(&[bucket, l, d], data);
    wave.bucket = bucket;
    wave.slots.extend(extra.slots);
    let seeds: Vec<u64> = wave.slots.iter().map(|s| s.slot.seed).collect();
    wave.opts.seed = slot_composition_seed(&seeds);
}

/// Block-boundary membership pass: complete cancelled slots with an error
/// and expired slots with the 504 deadline error, compact the survivors'
/// rows via the slot-remap gather, and migrate to the smaller covering
/// bucket when the wave shrank out of its current one. Returns `Ok(false)`
/// when no live slots remain.
fn sweep_and_remap<B: Backend>(
    set: &SamplerSet<'_, B>,
    wave: &mut Wave,
    m: &ContMetrics,
) -> std::result::Result<bool, String> {
    let any_leaving = wave.slots.iter().any(|s| s.slot.cancelled() || s.slot.expired());
    if !any_leaving {
        return Ok(true);
    }
    let mut live_idx: Vec<i32> = Vec::with_capacity(wave.slots.len());
    let mut kept = Vec::with_capacity(wave.slots.len());
    for (i, ls) in wave.slots.drain(..).enumerate() {
        if ls.slot.cancelled() {
            m.cancelled.inc();
            ls.slot.done.put_once(Err("request cancelled (client disconnected)".into()));
        } else if ls.slot.expired() {
            m.deadline_expired.inc();
            ls.slot.resolve_expired("block boundary");
        } else {
            live_idx.push(i as i32);
            kept.push(ls);
        }
    }
    if kept.is_empty() {
        return Ok(false);
    }
    // Compact rows so row i ↔ kept[i], through the device-side gather
    // artifact when the model ships one (pad rows re-point at row 0 —
    // their content is decoded but discarded, and a valid index keeps the
    // gather total).
    let old_sampler = set.select(wave.bucket);
    let mut idx = live_idx;
    idx.resize(wave.bucket, 0);
    let gathered = old_sampler
        .gather_slots_v(&Value::Host(wave.tokens.clone()), &idx)
        .map_err(|e| format!("slot remap gather failed: {e:#}"))?;
    let mut tokens = old_sampler
        .engine()
        .to_host(gathered)
        .map_err(|e| format!("slot remap sync failed: {e:#}"))?;
    // Migrate: a strictly smaller covering bucket exists now that the
    // wave shrank — slice the host rows down (the handoff is host data
    // anyway) and decode the rest of the flow in the small bucket.
    let new_bucket = covering_bucket(&set.buckets(), kept.len()).expect("non-empty bucket set");
    if new_bucket < wave.bucket {
        m.migrations.inc();
        let shape = tokens.shape().to_vec();
        let row = shape[1] * shape[2];
        let src = tokens.as_f32().map_err(|e| format!("wave tokens: {e:#}"))?;
        tokens = HostTensor::f32(
            &[new_bucket, shape[1], shape[2]],
            src[..new_bucket * row].to_vec(),
        );
        wave.bucket = new_bucket;
    }
    wave.tokens = tokens;
    wave.slots = kept;
    let seeds: Vec<u64> = wave.slots.iter().map(|s| s.slot.seed).collect();
    wave.opts.seed = slot_composition_seed(&seeds);
    Ok(true)
}

/// Decode this stage's span over the wave; padding accounting is per block
/// position (`sjd_padded_slot_blocks` — the quantity refill/migration/merge
/// exist to minimize).
fn cont_decode_span<B: Backend>(
    set: &SamplerSet<'_, B>,
    (lo, hi): (usize, usize),
    wave: &mut Wave,
    m: &ContMetrics,
) -> std::result::Result<(), SpanFail> {
    let sampler = set.select(wave.slots.len());
    let mut z = Value::Host(wave.tokens.clone());
    for pos in lo..hi {
        let (z_next, trace) = sampler
            .decode_block_at(pos, &z, &wave.opts)
            .map_err(|e| SpanFail::new(&format!("decode failed at position {pos}"), &e))?;
        m.padded_blocks.add((wave.bucket - wave.slots.len().min(wave.bucket)) as u64);
        m.block_iters.record(trace.steps as u64);
        m.host_syncs.record(trace.host_syncs as u64);
        for ls in &mut wave.slots {
            ls.traces.push(trace.clone());
        }
        z = z_next;
    }
    wave.tokens = sampler
        .engine()
        .to_host(z)
        .map_err(|e| SpanFail::new("stage handoff sync failed", &e))?;
    Ok(())
}

/// Send the wave downstream, or resolve every slot at the last stage.
fn forward_or_finish<B: Backend>(
    set: &SamplerSet<'_, B>,
    _span: (usize, usize),
    mut wave: Wave,
    tx: &Option<Arc<StageQueue<Wave>>>,
    governor: &Option<Arc<OverloadGovernor>>,
    m: &ContMetrics,
) {
    match tx {
        Some(tx) => {
            // The span's one documented host sync just happened and the
            // wave crosses a span boundary: count the handoff.
            m.handoffs.inc();
            wave.enqueued = Instant::now();
            if let Err(wave) = tx.send(wave) {
                // Downstream closed: complete the slots so nothing hangs.
                fail_wave(wave, "pipeline shut down mid-decode", m);
            }
        }
        None => {
            let sampler = set.select(wave.slots.len());
            match sampler.unpatchify(&wave.tokens) {
                Ok(images) => {
                    for (i, ls) in wave.slots.into_iter().enumerate() {
                        let latency = ls.slot.enqueued.elapsed();
                        m.latency.record_duration(latency);
                        // Completion side of the governor feedback loop:
                        // accepted-request latency EWMA.
                        if let Some(gov) = governor {
                            gov.observe_latency(latency);
                        }
                        m.images.inc();
                        ls.slot.done.put_once(Ok(images[i].clone()));
                    }
                    m.batches.inc();
                }
                Err(e) => fail_wave(wave, &format!("unpatchify failed: {e:#}"), m),
            }
        }
    }
}

/// Complete every slot of a failed wave with its own copy of the error.
/// `put_once` keeps this exactly-once against the watchdog having already
/// resolved the wave (the slot keeps whichever error landed first).
fn fail_wave(wave: Wave, msg: &str, m: &ContMetrics) {
    m.errors.inc();
    for ls in wave.slots {
        ls.slot.done.put_once(Err(msg.to_string()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_plan_maps_positions_modes_and_permutations() {
        let plan = stage_plan(&DecodePolicy::Selective { seq_blocks: 1 }, 4);
        assert_eq!(plan.len(), 4);
        // Position 0 decodes block K-1 = 3 (odd ⇒ reversed output).
        assert_eq!(plan[0].position, 0);
        assert_eq!(plan[0].block, 3);
        assert_eq!(plan[0].mode, BlockDecode::Sequential);
        assert!(plan[0].reversed);
        assert_eq!(plan[1].block, 2);
        assert_eq!(plan[1].mode, BlockDecode::Jacobi);
        assert!(!plan[1].reversed);
        assert_eq!(plan[3].position, 3);
        assert_eq!(plan[3].block, 0);
        assert!(!plan[3].reversed);
    }

    #[test]
    fn device_placement_contiguous_and_clamped() {
        // 4 stages on 2 devices: two contiguous halves.
        assert_eq!(device_placement(4, 2), vec![0, 0, 1, 1]);
        // Uneven split leans early, like window_partition.
        assert_eq!(device_placement(5, 2), vec![0, 0, 0, 1, 1]);
        assert_eq!(device_placement(4, 3), vec![0, 0, 1, 2]);
        // Single device (0 and 1 alike) is the legacy layout.
        assert_eq!(device_placement(4, 1), vec![0; 4]);
        assert_eq!(device_placement(4, 0), vec![0; 4]);
        // More devices than stages clamps: one stage per device, none idle.
        assert_eq!(device_placement(2, 8), vec![0, 1]);
        // Ordinals are non-decreasing and dense from 0.
        let p = device_placement(7, 3);
        assert!(p.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(p.iter().copied().max(), Some(2));
    }

    #[test]
    fn stage_queue_bounds_and_closes() {
        let q: Arc<StageQueue<u32>> = StageQueue::new(1);
        assert!(q.send(1).is_ok());
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.send(2));
        std::thread::sleep(Duration::from_millis(30));
        assert!(!t.is_finished(), "send past capacity must block");
        assert_eq!(q.recv(), Some(1));
        assert!(t.join().unwrap().is_ok());
        assert_eq!(q.recv(), Some(2));
        q.close();
        // A closed queue hands the item back instead of dropping it.
        assert_eq!(q.send(3).unwrap_err(), 3);
        assert_eq!(q.recv(), None);
    }

    #[test]
    fn depth_gate_blocks_at_depth() {
        let g = DepthGate::new(2);
        g.acquire();
        g.acquire();
        assert_eq!(g.current(), 2);
        let g2 = g.clone();
        let t = std::thread::spawn(move || {
            g2.acquire();
            g2.release();
        });
        std::thread::sleep(Duration::from_millis(30));
        assert!(!t.is_finished(), "third acquire must block at depth 2");
        g.release();
        t.join().unwrap();
        g.release();
        assert_eq!(g.current(), 0);
    }
}
