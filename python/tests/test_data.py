"""Synthetic datasets + Ising substrate sanity."""

import numpy as np
import pytest

from compile import data, ising


class TestSynthImages:
    def test_shapes_and_range(self):
        ds = data.make_dataset("synth10")
        x = ds.batch(8, seed=1)
        assert x.shape == (8, 16, 16, 3)
        assert x.min() >= -1.0 and x.max() <= 1.0

    def test_deterministic_given_seed(self):
        ds = data.make_dataset("synth10")
        a = ds.batch(4, seed=7)
        b = ds.batch(4, seed=7)
        np.testing.assert_array_equal(a, b)
        c = ds.batch(4, seed=8)
        assert np.abs(a - c).max() > 0.01

    def test_classes_are_distinct(self):
        """Different class parameter sets must produce distinct statistics —
        otherwise the dataset has no multi-modal structure to learn."""
        ds = data.SynthImages(16, 10, seed=10, noise=0.0)
        all_params = ds.params
        means = []
        for c in range(10):
            ds.params = [all_params[c]]
            ds.n_classes = 1
            means.append(ds.batch(16, seed=3).mean(axis=(0, 1, 2)))
        ds.params = all_params
        ds.n_classes = 10
        means = np.stack(means)
        dists = np.linalg.norm(means[:, None] - means[None, :], axis=-1)
        assert (dists[np.triu_indices(10, 1)] > 1e-3).mean() > 0.8

    def test_synth100_has_100_classes(self):
        ds = data.make_dataset("synth100")
        assert ds.n_classes == 100

    def test_blobfaces(self):
        ds = data.make_dataset("synthafhq")
        x = ds.batch(4, seed=2)
        assert x.shape == (4, 32, 32, 3)
        assert x.min() >= -1.0 and x.max() <= 1.0
        # Faces have spatial structure: column variance far from uniform noise.
        col_var = x.var(axis=1).mean()
        assert col_var > 0.01

    def test_digits_binary(self):
        ds = data.make_dataset("digits")
        x = ds.batch(6, seed=1)
        assert x.shape == (6, 196)
        assert set(np.unique(x)).issubset({-1.0, 1.0})
        # Dequantized version is continuous.
        xd = ds.batch(6, seed=1, dequant=0.3)
        assert len(np.unique(xd)) > 10
        # Glyphs have ink.
        assert (x > 0).mean() > 0.05


class TestIsing:
    def test_energy_convention_matches_rust(self):
        # All-up 4×4: E = −2·16 = −32 (each bond counted once, periodic).
        up = np.ones(16, np.float32)
        assert ising.energy(up, 4) == -32.0
        cb = np.array([1, -1] * 8, np.float32)
        cb = cb.reshape(4, 4)
        cb[1::2] *= -1
        assert ising.energy(cb.reshape(-1), 4) == 32.0

    def test_mcmc_disordered_at_T3(self):
        ds = ising.IsingDataset(side=8, temperature=3.0, n_configs=256, seed=5)
        e, m = ds.reference_stats()
        assert -0.9 < e < -0.3, e
        assert m < 0.45, m

    def test_dequantize_preserves_signs(self):
        spins = np.random.default_rng(0).choice([-1.0, 1.0], size=(100, 64)).astype(np.float32)
        x = ising.dequantize(spins, 0.25, seed=1)
        agree = (np.sign(x) == spins).mean()
        assert agree > 0.99

    def test_batches_vary(self):
        ds = ising.IsingDataset(side=4, temperature=3.0, n_configs=64, seed=2)
        a = ds.batch(8, seed=1)
        b = ds.batch(8, seed=2)
        assert a.shape == (8, 16)
        assert np.abs(a - b).max() > 0.1
