//! Overload benchmark over the **mock backend** — no artifacts needed, so
//! it runs everywhere (including the CI smoke step).
//!
//! Drives the full serving front door (HTTP → batcher → refill router) with
//! a 2×-oversubscribed burst trace against a capped queue, plus QoS
//! deadlines, with the quality-elastic governor attached (`--elastic`
//! equivalent). The property under test is **shed-instead-of-collapse**:
//! admission control and the degradation ladder keep *accepted* requests
//! fast while the excess is refused honestly, instead of every request
//! getting slow together.
//!
//! Gates (exit non-zero on failure):
//! * accepted-request p99 under the 2× burst stays within 2× of the
//!   uncontended baseline p99 on the same stack,
//! * at least one request was shed with HTTP 429 (admission control
//!   engaged),
//! * at least one deadline actually expired (HTTP 504 answered and
//!   `sjd_deadline_expired` advanced — queued purge or mid-flight block-
//!   boundary sweep),
//! * the governor stepped **up** the degradation ladder under pressure and
//!   stepped back **down to level 0** once the line went quiet,
//! * with the governor idle (level 0, τ = 0), per-request outputs are
//!   **bit-identical** to solo serial decodes — before the storm and again
//!   after recovery.
//!
//! ```bash
//! cargo bench --bench overload            # full run (6 burst rounds)
//! cargo bench --bench overload -- --quick # CI smoke (4 burst rounds)
//! ```

use anyhow::Result;
use sjd::coordinator::batcher::Batcher;
use sjd::coordinator::policy::{DecodePolicy, GovernorConfig, OverloadGovernor};
use sjd::coordinator::router::{Router, RouterConfig};
use sjd::coordinator::sampler::{SampleOptions, Sampler};
use sjd::coordinator::server::{Server, ServerConfig};
use sjd::metrics::Registry;
use sjd::testkit::mockflow::{MockLedger, MockServeBackend};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Per-slot artificial decode cost (per jstep/seqstep call, × batch size).
const SLOT_DELAY: Duration = Duration::from_micros(300);
/// Queue cap: in-flight wave (max batch 4) + this = total standing capacity.
const QUEUE_CAP: usize = 4;
/// Burst size: 2× the standing capacity (wave 4 + queue 4), so every round
/// must shed if admission control works at all.
const BURST: usize = 16;
/// Distinct request seeds (kept small so solo references are cached).
const SEED_SPACE: u64 = 6;

fn quick() -> bool {
    std::env::args().any(|a| a == "--quick") || std::env::var("SJD_QUICK").is_ok()
}

fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() as f64 - 1.0) * q) as usize]
}

fn opts() -> SampleOptions {
    let mut o = SampleOptions { policy: DecodePolicy::UniformJacobi, ..Default::default() };
    o.jacobi.tau = 0.0;
    o
}

/// Solo serial decode of one seed at bucket 1 — the bit-exactness oracle.
fn solo_reference(seed: u64) -> Result<Vec<f32>> {
    let be = MockServeBackend::new(&[1, 2, 4], Duration::ZERO, MockLedger::new());
    let sampler = Sampler::new(&be, "mock", 1)?;
    let z = sampler.sample_prior_slots(&[seed]);
    let out = sampler.decode_tokens(z, &opts())?;
    Ok(sampler.unpatchify(&out.tokens)?[0].data().to_vec())
}

/// One-shot POST with optional extra header lines (each `\r\n`-terminated);
/// returns the raw response text.
fn post(addr: &str, extra_headers: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    write!(
        s,
        "POST /generate HTTP/1.1\r\nHost: b\r\nConnection: close\r\n{extra_headers}Content-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut out = String::new();
    let _ = s.read_to_string(&mut out);
    out
}

fn status(resp: &str) -> u16 {
    resp.split(' ').nth(1).and_then(|s| s.parse().ok()).unwrap_or(0)
}

struct Stack {
    registry: Registry,
    batcher: Batcher,
    router: Router,
    stop: Arc<AtomicBool>,
    server_thread: std::thread::JoinHandle<anyhow::Result<()>>,
    addr: &'static str,
}

fn start_stack(addr: &'static str) -> Result<Stack> {
    let registry = Registry::new();
    let batcher = Batcher::with_cap(4, Duration::from_millis(2), QUEUE_CAP);
    batcher.bind_metrics(&registry);
    // The `serve --elastic --fidelity-budget 0.3` configuration: queue
    // signal at cap/2, tuner-style dwell, ladder ending at τ = 0.3.
    let governor = Arc::new(OverloadGovernor::new(
        4, // MockFlow::standard() blocks
        GovernorConfig {
            alpha: 0.4,
            queue_high: QUEUE_CAP as f64 / 2.0,
            dwell: 2,
            base_tau: 0.0,
            fidelity_budget: 0.3,
            s_max: 4,
            ..Default::default()
        },
        &registry,
    ));
    let ledger = MockLedger::new();
    let router = Router::start_with(
        RouterConfig {
            artifacts_dir: "mock".into(),
            model: "mock".into(),
            buckets: Vec::new(),
            workers: 1,
            options: opts(),
            pipeline_depth: 1,
            stage_threads: 0,
            refill: true,
            tuner: None,
            warm_cap: 0,
            governor: Some(governor),
            fault: Default::default(),
            replicas: 1,
            devices: 1,
        },
        batcher.clone(),
        registry.clone(),
        move |_| Ok(MockServeBackend::new(&[1, 2, 4], SLOT_DELAY, ledger.clone())),
    )?;
    let server = Server::with_config(
        addr,
        batcher.clone(),
        registry.clone(),
        ServerConfig { conn_threads: 24, ..Default::default() },
    );
    let stop = server.stop_flag();
    let server_thread = std::thread::spawn(move || server.run());
    for _ in 0..100 {
        if TcpStream::connect(addr).is_ok() {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    Ok(Stack { registry, batcher, router, stop, server_thread, addr })
}

impl Stack {
    fn level(&self) -> i64 {
        self.registry.gauge("sjd_degrade_level").get()
    }

    fn shutdown(self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        let _ = self.server_thread.join();
        self.router.shutdown();
    }
}

/// Direct-submission bit-exactness probe: every seed decoded through the
/// live stack must match its solo reference byte-for-byte (τ = 0 and the
/// governor at level 0 — Prop 3.2 exactness survives the serving machinery).
fn assert_bit_exact(stack: &Stack, solo: &[Vec<f32>], phase: &str) -> Result<()> {
    for (seed, want) in solo.iter().enumerate() {
        let img = stack
            .batcher
            .submit(7000 + seed as u64, seed as u64)
            .map_err(|e| anyhow::anyhow!("{phase}: submit: {e}"))?
            .wait()
            .map_err(|e| anyhow::anyhow!("{phase}: decode: {e}"))?;
        if img.data() != &want[..] {
            anyhow::bail!("{phase}: seed {seed} output differs from solo decode");
        }
    }
    Ok(())
}

fn main() -> Result<()> {
    let rounds = if quick() { 4 } else { 6 };
    let baseline_n = if quick() { 8 } else { 16 };
    println!(
        "=== overload: {rounds} rounds of {BURST}-burst against queue cap {QUEUE_CAP} \
         (elastic governor, mock backend) ==="
    );

    let solo: Vec<Vec<f32>> = (0..SEED_SPACE).map(solo_reference).collect::<Result<_>>()?;
    let stack = start_stack("127.0.0.1:8541")?;

    // --- Phase 1: uncontended baseline (governor idle at level 0). -------
    assert_bit_exact(&stack, &solo, "baseline")?;
    let mut base_lat = Vec::new();
    for i in 0..baseline_n {
        let t0 = Instant::now();
        let resp = post(stack.addr, "", &format!("{{\"n\": 1, \"seed\": {}}}", i % SEED_SPACE));
        anyhow::ensure!(status(&resp) == 200, "uncontended request failed: {resp}");
        base_lat.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    base_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let base_p99 = pct(&base_lat, 0.99);
    anyhow::ensure!(stack.level() == 0, "governor must stay idle uncontended");

    // --- Phase 2: 2× burst rounds with mixed deadlines. ------------------
    let mut accepted = Vec::new();
    let (mut shed_429, mut expired_504, mut other) = (0u64, 0u64, 0u64);
    let mut max_level = 0i64;
    for round in 0..rounds {
        let mut clients = Vec::new();
        for j in 0..BURST {
            let addr = stack.addr;
            let seed = (round * BURST + j) as u64 % SEED_SPACE;
            // A quarter of each burst is latency-bounded: a 6 ms deadline
            // under ~10 ms of queue+decode expires some of them for real.
            let headers: &'static str =
                if j % 4 == 3 { "X-SJD-Deadline-Ms: 6\r\n" } else { "" };
            clients.push(std::thread::spawn(move || {
                let t0 = Instant::now();
                let resp = post(addr, headers, &format!("{{\"n\": 1, \"seed\": {seed}}}"));
                (status(&resp), t0.elapsed().as_secs_f64() * 1e3)
            }));
        }
        for c in clients {
            let (code, ms) = c.join().expect("client thread");
            match code {
                200 => accepted.push(ms),
                429 => shed_429 += 1,
                504 => expired_504 += 1,
                _ => other += 1,
            }
        }
        max_level = max_level.max(stack.level());
        std::thread::sleep(Duration::from_millis(5));
    }
    accepted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let burst_p99 = pct(&accepted, 0.99);
    let expired_total = stack.registry.counter("sjd_deadline_expired").get();

    // --- Phase 3: pressure clears → ladder walks back to level 0. --------
    let mut recovered = false;
    for i in 0..60u64 {
        let resp = post(stack.addr, "", &format!("{{\"n\": 1, \"seed\": {}}}", i % SEED_SPACE));
        let _ = status(&resp);
        if stack.level() == 0 && i >= 4 {
            recovered = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(3));
    }
    let elastic_tau = stack.registry.gauge("sjd_elastic_tau").get();

    // --- Phase 4: back at level 0, outputs are exact again. --------------
    let exact_after = assert_bit_exact(&stack, &solo, "post-recovery");

    println!("\n=== summary ===");
    println!(
        "baseline p99 {base_p99:.1} ms | burst accepted p99 {burst_p99:.1} ms \
         ({:.2}x, {} accepted) | 429 shed {shed_429} | 504 expired {expired_504} \
         (counter {expired_total}) | other {other} | max ladder level {max_level} \
         | recovered level {} (tau gauge {elastic_tau})",
        burst_p99 / base_p99.max(1e-9),
        accepted.len(),
        stack.level(),
    );
    stack.shutdown();

    let p99_ok = burst_p99 <= 2.0 * base_p99 && !accepted.is_empty();
    let shed_ok = shed_429 >= 1;
    let deadline_ok = expired_504 >= 1 && expired_total >= 1;
    let gov_ok = max_level >= 1 && recovered && elastic_tau == 0;
    let exact_ok = exact_after.is_ok() && other == 0;
    if let Err(e) = &exact_after {
        eprintln!("exactness: {e:#}");
    }
    if p99_ok && shed_ok && deadline_ok && gov_ok && exact_ok {
        println!("PASS: overload sheds and degrades instead of collapsing, then recovers exactly");
        Ok(())
    } else {
        println!(
            "FAIL: p99_ok={p99_ok} (need ≤2x) shed_ok={shed_ok} deadline_ok={deadline_ok} \
             gov_ok={gov_ok} exact_ok={exact_ok}"
        );
        std::process::exit(1);
    }
}
