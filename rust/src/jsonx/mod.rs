//! Minimal JSON parser/emitter (serde_json substitute — crates.io is not
//! reachable in this build environment, see DESIGN.md §2).
//!
//! Supports the full JSON grammar; `\u` surrogate pairs are combined and lone
//! surrogates rejected. Numbers are stored as `f64`.

mod parse;
mod value;
mod write;

pub use parse::{parse, ParseError};
pub use value::Value;
pub use write::to_string_pretty;

#[cfg(test)]
mod tests;
