//! Jacobi decoding driver (paper Alg 1).
//!
//! One Jacobi *step* is an AOT artifact call `(k, z_t, y) → (z_{t+1}, resid)`
//! that updates every position of the sequence in parallel from the previous
//! iterate (the L1 Pallas hot path). This driver owns the L3 concerns: the
//! initialization strategy, the τ stopping rule on ‖z^t − z^{t−1}‖∞, the
//! worst-case `L` iteration guard (Prop 3.2 guarantees exactness at `t = L`),
//! and per-layer statistics for the selective policy / paper tables.

use crate::runtime::{Backend, HostTensor};
use crate::tensor::Pcg64;
use anyhow::Result;
use std::time::{Duration, Instant};

/// How `z⁰` is initialized (paper Fig 6 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitStrategy {
    /// `z⁰ = 0` (paper default, Alg 1).
    Zeros,
    /// `z⁰ ~ N(0, I)`.
    Normal,
    /// `z⁰ = z_{k+1}` (previous layer's output — the Jacobi input itself).
    PrevLayer,
}

impl InitStrategy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "zeros" => Some(InitStrategy::Zeros),
            "normal" => Some(InitStrategy::Normal),
            "prev" | "prev_layer" => Some(InitStrategy::PrevLayer),
            _ => None,
        }
    }
}

/// Driver configuration.
#[derive(Clone, Debug)]
pub struct JacobiConfig {
    /// Stopping threshold τ on ‖z^t − z^{t−1}‖∞ (paper default 0.5).
    pub tau: f32,
    /// Hard iteration cap; `None` ⇒ the sequence length `L` (Prop 3.2 bound).
    pub max_iters: Option<usize>,
    pub init: InitStrategy,
    /// Seed for `InitStrategy::Normal`.
    pub seed: u64,
}

impl Default for JacobiConfig {
    fn default() -> Self {
        JacobiConfig { tau: 0.5, max_iters: None, init: InitStrategy::Zeros, seed: 0 }
    }
}

/// Statistics of one Jacobi decode of one block.
#[derive(Clone, Debug)]
pub struct JacobiStats {
    pub block: usize,
    pub iterations: usize,
    pub wall: Duration,
    /// Residual ‖z^t − z^{t−1}‖∞ after each iteration.
    pub residuals: Vec<f32>,
    /// Whether the τ criterion was reached (vs hitting the iteration cap).
    pub converged: bool,
}

/// Decode block `k` by Jacobi iteration.
///
/// `y` is the block input `z_{k+1}` with shape (B, L, D); the artifact
/// `{model}_block_jstep_b{B}` computes one parallel update plus the residual
/// max over the batch. `mask_o > 0` applies the paper's eq-6 dependency mask
/// (used for the Fig 1/2 redundancy experiments); `mask_o = 0` is the exact
/// update of Alg 1.
pub fn jacobi_decode_block<B: Backend>(
    engine: &B,
    artifact: &str,
    block: usize,
    y: &HostTensor,
    seq_len: usize,
    cfg: &JacobiConfig,
    mask_o: usize,
) -> Result<(HostTensor, JacobiStats)> {
    let t0 = Instant::now();
    let mut z = init_iterate(y, cfg);
    let cap = cfg.max_iters.unwrap_or(seq_len);
    let mut residuals = Vec::new();
    let mut converged = false;

    let mut iterations = 0;
    while iterations < cap {
        let out = engine.call(
            artifact,
            &[
                HostTensor::scalar_i32(block as i32),
                z,
                y.clone(),
                HostTensor::scalar_i32(mask_o as i32),
            ],
        )?;
        let mut it = out.into_iter();
        let z_next = it.next().expect("jstep returns z'");
        let resid_t = it.next().expect("jstep returns residual");
        let resid = resid_t.as_f32()?.iter().copied().fold(0.0f32, f32::max);
        residuals.push(resid);
        z = z_next;
        iterations += 1;
        if resid < cfg.tau {
            converged = true;
            break;
        }
    }

    Ok((
        z,
        JacobiStats { block, iterations, wall: t0.elapsed(), residuals, converged },
    ))
}

/// Build the initial iterate `z⁰` per the configured strategy.
pub fn init_iterate(y: &HostTensor, cfg: &JacobiConfig) -> HostTensor {
    match cfg.init {
        InitStrategy::Zeros => HostTensor::f32(y.shape(), vec![0.0; y.len()]),
        InitStrategy::Normal => {
            let mut rng = Pcg64::seed(cfg.seed);
            HostTensor::f32(y.shape(), (0..y.len()).map(|_| rng.next_gaussian()).collect())
        }
        InitStrategy::PrevLayer => y.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_strategies() {
        let y = HostTensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let zeros = init_iterate(&y, &JacobiConfig::default());
        assert_eq!(zeros.as_f32().unwrap(), &[0.0; 6]);

        let prev = init_iterate(
            &y,
            &JacobiConfig { init: InitStrategy::PrevLayer, ..Default::default() },
        );
        assert_eq!(prev.as_f32().unwrap(), y.as_f32().unwrap());

        let n1 = init_iterate(
            &y,
            &JacobiConfig { init: InitStrategy::Normal, seed: 5, ..Default::default() },
        );
        let n2 = init_iterate(
            &y,
            &JacobiConfig { init: InitStrategy::Normal, seed: 5, ..Default::default() },
        );
        assert_eq!(n1.as_f32().unwrap(), n2.as_f32().unwrap());
        assert_ne!(n1.as_f32().unwrap(), zeros.as_f32().unwrap());
    }

    #[test]
    fn parse_init() {
        assert_eq!(InitStrategy::parse("zeros"), Some(InitStrategy::Zeros));
        assert_eq!(InitStrategy::parse("normal"), Some(InitStrategy::Normal));
        assert_eq!(InitStrategy::parse("prev"), Some(InitStrategy::PrevLayer));
        assert_eq!(InitStrategy::parse("bogus"), None);
    }

    #[test]
    fn default_config_matches_paper() {
        let c = JacobiConfig::default();
        assert_eq!(c.tau, 0.5);
        assert_eq!(c.init, InitStrategy::Zeros);
        assert!(c.max_iters.is_none());
    }
}
