"""2-D Ising model (python side): Metropolis MCMC dataset generation for the
MAF Boltzmann experiment (paper §E.3, Table A5).

The rust `physics::ising` module mirrors the observables for evaluation; this
module only produces *training data*: spin configurations from the T = 3.0
disordered phase, dequantized to continuous values for MLE flow training
(substitution for the paper's reverse-KL objective — same target
distribution, documented in DESIGN.md §5).
"""

import numpy as np


def energy(spins: np.ndarray, side: int) -> float:
    """E = −Σ_<ij> s_i s_j with periodic boundaries (bonds counted once)."""
    lat = spins.reshape(side, side)
    return float(-(lat * np.roll(lat, -1, 0)).sum() - (lat * np.roll(lat, -1, 1)).sum())


def metropolis_chain(side: int, temperature: float, n_samples: int,
                     sweeps_between: int, burn_in: int, seed: int) -> np.ndarray:
    """(n_samples, side²) of ±1 spins from single-spin-flip Metropolis."""
    rng = np.random.default_rng(seed)
    n = side
    beta = 1.0 / temperature
    spins = rng.choice(np.array([-1, 1], np.int8), size=(n, n))
    out = np.empty((n_samples, n * n), np.float32)

    def sweep():
        # Vectorized checkerboard sweep (both parities).
        for parity in (0, 1):
            nb = (np.roll(spins, 1, 0) + np.roll(spins, -1, 0)
                  + np.roll(spins, 1, 1) + np.roll(spins, -1, 1))
            delta_e = 2.0 * spins * nb
            accept = (delta_e <= 0) | (rng.random((n, n)) < np.exp(-beta * np.clip(delta_e, 0, None)))
            mask = ((np.add.outer(np.arange(n), np.arange(n)) % 2) == parity)
            spins[accept & mask] *= -1

    for _ in range(burn_in):
        sweep()
    for i in range(n_samples):
        for _ in range(sweeps_between):
            sweep()
        out[i] = spins.reshape(-1).astype(np.float32)
    return out


def dequantize(spins: np.ndarray, std: float, seed: int) -> np.ndarray:
    """Continuous relaxation: x = s + N(0, std²); sign(x) recovers s w.h.p."""
    rng = np.random.default_rng(seed)
    return spins + std * rng.standard_normal(spins.shape).astype(np.float32)


class IsingDataset:
    """Pre-generated MCMC configurations served as training batches."""

    def __init__(self, side: int = 8, temperature: float = 3.0,
                 n_configs: int = 4096, seed: int = 11, dequant_std: float = 0.25):
        self.side = side
        self.temperature = temperature
        self.dequant_std = dequant_std
        self.configs = metropolis_chain(
            side, temperature, n_configs, sweeps_between=2, burn_in=200, seed=seed)

    def batch(self, n: int, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        idx = rng.integers(0, len(self.configs), size=n)
        return dequantize(self.configs[idx], self.dequant_std, seed + 1)

    def reference_stats(self):
        """Ground-truth ⟨E⟩/site and ⟨|M|⟩ of the MCMC configurations."""
        sites = self.side ** 2
        e = np.array([energy(c, self.side) for c in self.configs]) / sites
        m = np.abs(self.configs.mean(axis=1))
        return float(e.mean()), float(m.mean())
