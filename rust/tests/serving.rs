//! Serving-stack integration: batcher + router workers + HTTP server.
//!
//! Two tiers: hermetic tests over the shared mock backend
//! (`sjd::testkit::mockflow`) — bucket routing, padding accounting,
//! concurrent request handling, keep-alive — and artifact-driven end-to-end
//! tests over real TCP + PJRT that skip when artifacts are missing.

use sjd::coordinator::batcher::Batcher;
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::router::{Router, RouterConfig};
use sjd::coordinator::sampler::SampleOptions;
use sjd::coordinator::server::{Server, ServerConfig};
use sjd::metrics::Registry;
use sjd::testkit::mockflow::{MockLedger, MockServeBackend};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::env::var("SJD_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built");
        None
    }
}

/// One-shot POST: asks the server to close the connection so the whole
/// response can be slurped with `read_to_string`.
fn post(addr: &str, path: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    write!(
        s,
        "POST {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

/// One-shot GET (`Connection: close`, see [`post`]).
fn get(addr: &str, path: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n").unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

/// One HTTP response off a keep-alive connection (stream stays usable).
fn read_response(reader: &mut impl BufRead) -> String {
    let (head, body) = sjd::testkit::http::read_response(reader).expect("response");
    head + &String::from_utf8_lossy(&body)
}

/// Boot a single-worker router over the shared mock backend.
fn mock_router(
    buckets: &[usize],
    slot_delay: Duration,
    policy: DecodePolicy,
    batcher: &Batcher,
    registry: &Registry,
    ledger: &Arc<MockLedger>,
) -> Router {
    let buckets = buckets.to_vec();
    let ledger = ledger.clone();
    Router::start_with(
        RouterConfig {
            artifacts_dir: "unused-by-mock".into(),
            model: "mock".into(),
            buckets: Vec::new(), // = every bucket the mock claims lowered
            workers: 1,
            options: SampleOptions { policy, ..Default::default() },
        },
        batcher.clone(),
        registry.clone(),
        move |_widx| Ok(MockServeBackend::new(&buckets, slot_delay, ledger.clone())),
    )
    .expect("mock router")
}

fn start_server(server: Server) -> (Arc<AtomicBool>, std::thread::JoinHandle<anyhow::Result<()>>) {
    let addr = server.addr().to_string();
    let stop = server.stop_flag();
    let t = std::thread::spawn(move || server.run());
    for _ in 0..100 {
        if TcpStream::connect(&addr).is_ok() {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    (stop, t)
}

fn stop_server(
    addr: &str,
    stop: Arc<AtomicBool>,
    t: std::thread::JoinHandle<anyhow::Result<()>>,
) {
    stop.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(addr);
    let _ = t.join();
}

// ---------------------------------------------------------------------------
// Hermetic mock-backend serving tests
// ---------------------------------------------------------------------------

#[test]
fn healthz_and_metrics_respond_while_decode_in_flight() {
    // Sequential policy + 25 ms per seqstep call ⇒ each n=1 decode takes
    // ~K·L·25 ms = 800 ms on the single worker. With connection handling on
    // the pool, /healthz and /metrics must answer mid-decode instead of
    // queueing behind the generations.
    let addr = "127.0.0.1:8501";
    let registry = Registry::new();
    let batcher = Batcher::new(1, Duration::from_millis(2));
    let ledger = MockLedger::new();
    let router = mock_router(
        &[1],
        Duration::from_millis(25),
        DecodePolicy::Sequential,
        &batcher,
        &registry,
        &ledger,
    );
    let server = Server::with_config(
        addr,
        batcher.clone(),
        registry.clone(),
        ServerConfig { conn_threads: 4, ..Default::default() },
    );
    let (stop, t) = start_server(server);

    let gen_done = [Arc::new(AtomicBool::new(false)), Arc::new(AtomicBool::new(false))];
    let mut gens = Vec::new();
    for (i, done) in gen_done.iter().enumerate() {
        let done = done.clone();
        gens.push(std::thread::spawn(move || {
            let resp = post(addr, "/generate", &format!("{{\"n\": 1, \"seed\": {i}}}"));
            done.store(true, Ordering::SeqCst);
            resp
        }));
    }

    // Probe while the first decode is provably still running.
    std::thread::sleep(Duration::from_millis(250));
    let t_probe = Instant::now();
    let h = get(addr, "/healthz");
    let m = get(addr, "/metrics");
    let probe_wall = t_probe.elapsed();
    assert!(h.starts_with("HTTP/1.1 200"), "{h}");
    assert!(m.starts_with("HTTP/1.1 200"), "{m}");
    assert!(m.contains("sjd_http_requests"), "{m}");
    assert!(
        !gen_done[0].load(Ordering::SeqCst) && !gen_done[1].load(Ordering::SeqCst),
        "probes must return before the generations finish"
    );
    assert!(
        probe_wall < Duration::from_millis(500),
        "probe took {probe_wall:?} — serialized behind a decode?"
    );

    for g in gens {
        let resp = g.join().unwrap();
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    }
    stop_server(addr, stop, t);
    router.shutdown();
}

#[test]
fn n1_generate_uses_bucket_1_with_zero_padding() {
    // The headline property: with buckets {1,2,4,8} lowered, a lone n=1
    // request decodes through the b1 artifacts and pads nothing.
    let addr = "127.0.0.1:8502";
    let registry = Registry::new();
    let batcher = Batcher::new(8, Duration::from_millis(10));
    let ledger = MockLedger::new();
    let router = mock_router(
        &[1, 2, 4, 8],
        Duration::ZERO,
        DecodePolicy::Selective { seq_blocks: 1 },
        &batcher,
        &registry,
        &ledger,
    );
    let server = Server::new(addr, batcher.clone(), registry.clone());
    let (stop, t) = start_server(server);

    let resp = post(addr, "/generate", r#"{"n": 1, "seed": 3}"#);
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    let body = resp.split("\r\n\r\n").nth(1).unwrap();
    let v = sjd::jsonx::parse(body).expect("json body");
    assert_eq!(v.req_arr("images_png_b64").unwrap().len(), 1);

    assert_eq!(registry.counter("sjd_padded_slots").get(), 0, "n=1 must pad zero slots");
    assert_eq!(registry.counter("sjd_bucket_1_batches").get(), 1);
    assert!(ledger.count_containing("_b1") > 0, "decode must run the b1 artifacts");
    // Per-block convergence observability: one sjd_block_iters +
    // sjd_host_syncs sample per decoded block (mock flow has 4 blocks).
    assert_eq!(registry.histogram("sjd_block_iters").count(), 4);
    assert_eq!(registry.histogram("sjd_host_syncs").count(), 4);
    assert!(registry.histogram("sjd_host_syncs").snapshot().max >= 1);
    for b in [2usize, 4, 8] {
        assert_eq!(ledger.count_containing(&format!("_b{b}")), 0, "bucket {b} must stay idle");
    }
    stop_server(addr, stop, t);
    router.shutdown();
}

#[test]
fn three_slot_batch_rounds_up_to_bucket_4_with_one_pad() {
    let registry = Registry::new();
    let batcher = Batcher::new(4, Duration::from_millis(150));
    let ledger = MockLedger::new();
    let router = mock_router(
        &[1, 2, 4],
        Duration::ZERO,
        DecodePolicy::Selective { seq_blocks: 1 },
        &batcher,
        &registry,
        &ledger,
    );

    // 3 slots land together, the 4-slot deadline lapses, the worker picks
    // bucket 4 and pads exactly one slot.
    let handles: Vec<_> = (0..3).map(|i| batcher.submit(7, i).unwrap()).collect();
    for h in handles {
        let img = h.wait().expect("decoded image");
        assert_eq!(img.ndim(), 3);
    }
    assert_eq!(registry.counter("sjd_bucket_4_batches").get(), 1);
    assert_eq!(registry.counter("sjd_padded_slots").get(), 1);
    assert!(ledger.count_containing("_b4") > 0);
    assert_eq!(ledger.count_containing("_b2"), 0);

    // A lone follow-up slot drops to bucket 1 — no new padding.
    batcher.submit(8, 9).unwrap().wait().expect("decoded image");
    assert_eq!(registry.counter("sjd_bucket_1_batches").get(), 1);
    assert_eq!(registry.counter("sjd_padded_slots").get(), 1, "bucket 1 adds no padding");
    let fill = registry.histogram("sjd_batch_fill").snapshot();
    assert_eq!(fill.count, 2);
    assert_eq!(fill.max, 3, "batch fill records real slots, not the padded bucket");
    router.shutdown();
}

#[test]
fn keepalive_connection_serves_multiple_requests() {
    // No router needed: /healthz and /metrics don't touch the batcher.
    let addr = "127.0.0.1:8503";
    let registry = Registry::new();
    let server = Server::new(addr, Batcher::new(1, Duration::from_millis(5)), registry.clone());
    let (stop, t) = start_server(server);

    let s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut writer = s.try_clone().unwrap();
    let mut reader = BufReader::new(s);
    // Two requests ride the HTTP/1.1 default keep-alive; the third asks for
    // close and the server must honor it.
    for _ in 0..2 {
        write!(writer, "GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
        let resp = read_response(&mut reader);
        assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
        assert!(resp.contains("Connection: keep-alive"), "{resp}");
    }
    write!(writer, "GET /metrics HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n").unwrap();
    let resp = read_response(&mut reader);
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    assert!(resp.contains("Connection: close"), "{resp}");
    let mut rest = String::new();
    reader.read_to_string(&mut rest).expect("server closes after Connection: close");
    assert!(rest.is_empty());

    assert_eq!(registry.counter("sjd_http_requests").get(), 3);
    assert_eq!(registry.counter("sjd_http_keepalive_reuses").get(), 2);
    stop_server(addr, stop, t);
}

#[test]
fn generate_after_shutdown_returns_500_not_hang() {
    // Post-close submissions fail fast (Batcher::submit), so a /generate
    // racing shutdown gets an immediate 500 instead of waiting forever on a
    // slot no worker will ever decode.
    let addr = "127.0.0.1:8504";
    let registry = Registry::new();
    let batcher = Batcher::new(4, Duration::from_millis(5));
    let server = Server::new(addr, batcher.clone(), registry.clone());
    let (stop, t) = start_server(server);

    batcher.close(); // simulates router.shutdown() while the listener lives
    let resp = post(addr, "/generate", r#"{"n": 1}"#);
    assert!(resp.starts_with("HTTP/1.1 500"), "{resp}");
    let body = resp.split("\r\n\r\n").nth(1).unwrap();
    let v = sjd::jsonx::parse(body).expect("error body is JSON");
    assert!(v.get("error").is_some());
    stop_server(addr, stop, t);
}

// ---------------------------------------------------------------------------
// Artifact-driven end-to-end tests (skip without artifacts)
// ---------------------------------------------------------------------------

#[test]
fn serve_generate_and_metrics_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    let addr = "127.0.0.1:8497";
    let registry = Registry::new();
    let batcher = Batcher::new(1, Duration::from_millis(5));
    let router = Router::start(
        RouterConfig {
            artifacts_dir: dir,
            model: "tf10".into(),
            buckets: vec![1],
            workers: 1,
            options: SampleOptions::default(),
        },
        batcher.clone(),
        registry.clone(),
    )
    .expect("router");

    let server = Server::new(addr, batcher, registry.clone());
    let (stop, t) = start_server(server);

    // Health.
    let h = get(addr, "/healthz");
    assert!(h.starts_with("HTTP/1.1 200"), "{h}");

    // Generate 2 images.
    let resp = post(addr, "/generate", r#"{"n": 2, "seed": 5}"#);
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    let body = resp.split("\r\n\r\n").nth(1).unwrap();
    let v = sjd::jsonx::parse(body).expect("json body");
    let imgs = v.req_arr("images_png_b64").unwrap();
    assert_eq!(imgs.len(), 2);
    // Base64 payloads decode to PNG magic.
    let b64 = imgs[0].as_str().unwrap();
    assert!(b64.len() > 100);
    assert!(b64.starts_with("iVBOR"), "not a PNG payload: {}", &b64[..16]);

    // Determinism: same seed → identical payloads.
    let resp2 = post(addr, "/generate", r#"{"n": 2, "seed": 5}"#);
    let body2 = resp2.split("\r\n\r\n").nth(1).unwrap();
    let v2 = sjd::jsonx::parse(body2).unwrap();
    assert_eq!(
        v.req_arr("images_png_b64").unwrap()[0],
        v2.req_arr("images_png_b64").unwrap()[0],
        "same seed must reproduce the same image"
    );

    // Metrics advanced.
    let m = get(addr, "/metrics");
    assert!(m.contains("sjd_images_generated"), "{m}");
    assert!(m.contains("sjd_http_requests"));
    assert!(m.contains("sjd_padded_slots"));

    // Bad request handled.
    let bad = post(addr, "/generate", "{invalid json");
    assert!(bad.starts_with("HTTP/1.1 400"), "{bad}");
    let nf = get(addr, "/nope");
    assert!(nf.starts_with("HTTP/1.1 404"));

    // Shutdown.
    stop_server(addr, stop, t);
    router.shutdown();
}

#[test]
fn server_answers_malformed_requests_without_backend() {
    // The HTTP front end's defensive paths need no artifacts: header-cap
    // violations and bad JSON must get a 400 response (not a silent
    // connection reset), with a body that is itself valid JSON.
    let addr = "127.0.0.1:8499";
    let registry = Registry::new();
    let batcher = Batcher::new(1, Duration::from_millis(5));
    let server = Server::new(addr, batcher, registry);
    let (stop, t) = start_server(server);

    // Header flood → answered 400.
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut req = String::from("GET /healthz HTTP/1.1\r\n");
    for i in 0..200 {
        req.push_str(&format!("X-H{i}: v\r\n"));
    }
    req.push_str("\r\n");
    s.write_all(req.as_bytes()).unwrap();
    let mut out = String::new();
    let _ = s.read_to_string(&mut out);
    assert!(out.starts_with("HTTP/1.1 400"), "{out}");

    // Bad JSON body → 400, and the error body parses as JSON.
    let resp = post(addr, "/generate", "{invalid json");
    assert!(resp.starts_with("HTTP/1.1 400"), "{resp}");
    let body = resp.split("\r\n\r\n").nth(1).unwrap();
    assert!(sjd::jsonx::parse(body).is_ok(), "error body must be valid JSON: {body}");

    // Well-formed requests still served.
    let h = get(addr, "/healthz");
    assert!(h.starts_with("HTTP/1.1 200"), "{h}");

    stop_server(addr, stop, t);
}

#[test]
fn batcher_groups_concurrent_requests() {
    let Some(dir) = artifacts_dir() else { return };
    let registry = Registry::new();
    // Batch of 8 with generous wait: 8 concurrent submissions form 1 batch.
    let batcher = Batcher::new(8, Duration::from_millis(500));
    let router = Router::start(
        RouterConfig {
            artifacts_dir: dir,
            model: "tf10".into(),
            buckets: vec![8],
            workers: 1,
            options: SampleOptions::default(),
        },
        batcher.clone(),
        registry.clone(),
    )
    .expect("router");

    let handles: Vec<_> = (0..8).map(|i| batcher.submit(i, 9).unwrap()).collect();
    for h in handles {
        let img = h.wait().expect("decoded image");
        assert_eq!(img.ndim(), 3);
    }
    // One full batch, decoded via the 8-bucket with no padding.
    let snap = registry.histogram("sjd_batch_fill").snapshot();
    assert_eq!(snap.count, 1);
    assert!(snap.max == 8, "batch fill {}", snap.max);
    assert_eq!(registry.counter("sjd_padded_slots").get(), 0);
    router.shutdown();
}
