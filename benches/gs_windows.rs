//! **GS-Jacobi windows**: total position-updates and jstep calls of windowed
//! GS-Jacobi decoding vs the UJD / SJD baselines at equal τ.
//!
//! The work metric is [`BlockTrace::position_updates`]: full-sequence Jacobi
//! re-updates all `L` positions every iteration even after most of the
//! prefix converged; the windowed sweep (`gs_jacobi_decode_block_v`) only
//! updates the active window, cutting a strongly coupled block from
//! `O(L²)` toward `O(L²/W)`. The acceptance property reported here:
//! **strictly fewer total position-updates than UJD at equal τ** (the
//! hermetic counterpart lives in `rust/tests/mock_backend.rs::
//! gs_fewer_position_updates_than_ujd_at_equal_tau`).

mod common;

use common::*;
use sjd::benchkit::Report;
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::sampler::{SampleOptions, Sampler};
use sjd::tensor::Pcg64;

fn main() -> anyhow::Result<()> {
    let engine = engine_or_skip();
    let model = if engine.manifest().model("tfafhq").is_ok() { "tfafhq" } else { "tf10" };
    let batch = *engine.manifest().model(model)?.batch_sizes.iter().min().unwrap();
    let sampler = Sampler::new(&engine, model, batch)?;
    if !sampler.has_gs_artifact() {
        println!(
            "SKIP: {} not lowered — re-run `make artifacts` to add the windowed jstep",
            sampler.jstep_win_artifact()
        );
        return Ok(());
    }

    let batches = if quick() { 1 } else { 3 };
    let tau = 0.5f32; // paper-default τ for every policy (equal-τ comparison)
    let ll = sampler.meta.seq_len;
    let mut report = Report::new(format!(
        "GS-Jacobi windows — position-updates vs UJD/SJD at τ = {tau} ({model})"
    ));

    // Two savings regimes (see jacobi module docs): strongly coupled blocks
    // (iterations ≈ L) profit from coarse windows (≈ L²/W updates); weakly
    // coupled blocks (iterations t ≪ L) only once the window length drops
    // below t (then the per-window exactness cap bounds updates by len·L).
    // Sweep both ends; fine windows trade extra step calls for the update
    // savings.
    let mut policies = vec![
        DecodePolicy::UniformJacobi,
        DecodePolicy::Selective { seq_blocks: 1 },
    ];
    for w in [2, 4, 8, ll / 4, ll / 2, ll] {
        if w >= 2 && policies.iter().all(|p| *p != DecodePolicy::GsJacobi { windows: w }) {
            policies.push(DecodePolicy::GsJacobi { windows: w });
        }
    }
    let mut rows = Vec::new();
    let mut ujd_updates = None;
    for policy in policies {
        let label = policy.label();
        let mut opts = SampleOptions { policy, ..Default::default() };
        opts.jacobi.tau = tau;
        let mut updates = 0usize;
        let mut calls = 0usize;
        let mut wall = 0.0f64;
        for b in 0..batches {
            opts.seed = 100 + b as u64;
            let mut rng = Pcg64::seed(opts.seed);
            let z = sampler.sample_prior(&mut rng);
            let out = sampler.decode_tokens(z, &opts)?;
            updates += out.total_position_updates();
            calls += out.traces.iter().map(|t| t.steps).sum::<usize>();
            wall += out.total_wall.as_secs_f64();
        }
        if matches!(opts.policy, DecodePolicy::UniformJacobi) {
            ujd_updates = Some(updates);
        }
        let saved = ujd_updates
            .map(|u| format!("{:.1}%", 100.0 * (1.0 - updates as f64 / u as f64)))
            .unwrap_or_else(|| "—".into());
        println!(
            "{label:>14}: {updates:>8} position-updates, {calls:>5} step calls, {:.3}s",
            wall
        );
        rows.push(vec![
            label,
            updates.to_string(),
            calls.to_string(),
            saved,
            format!("{wall:.3}"),
        ]);
    }
    report.table(
        &["policy", "position-updates", "step calls", "saved vs UJD", "wall (s)"],
        &rows,
    );

    // The acceptance check: the windowed sweep must beat UJD on total
    // position-updates at equal τ for at least one window count (whenever
    // UJD needs ≥ 2 iterations anywhere, W = L is a guaranteed witness:
    // ≤ L updates per block vs iterations × L).
    let ujd = ujd_updates.expect("UJD measured first");
    let best_gs = rows
        .iter()
        .filter(|r| r[0].starts_with("GS-Jacobi"))
        .map(|r| r[1].parse::<usize>().unwrap())
        .min()
        .expect("at least one GS row");
    let gs_ok = best_gs < ujd;
    report.note(if gs_ok {
        "PASS: windowed GS-Jacobi performed strictly fewer total position-updates than UJD at equal τ."
    } else {
        "FAIL: no GS-Jacobi configuration reduced position-updates vs UJD."
    });
    report.note(
        "Paper shape (arXiv 2505.12849): coarse windows cut strongly coupled blocks \
         toward L²/W; on weakly coupled blocks the savings appear once the window \
         length drops below the block's iteration count (at the cost of more step calls).",
    );
    report.finish();
    anyhow::ensure!(gs_ok, "GS-Jacobi did not beat UJD on position-updates");
    Ok(())
}
