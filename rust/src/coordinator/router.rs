//! Multi-worker router: each worker is a dedicated OS thread owning its own
//! PJRT [`Engine`] + [`Sampler`] (engines are `Rc`-based and thread-pinned),
//! all pulling batches from the shared [`Batcher`] queue — work-stealing via
//! a single MPMC queue gives least-loaded dispatch for free.

use super::batcher::Batcher;
use super::sampler::{SampleOptions, Sampler};
use crate::metrics::Registry;
use crate::runtime::Engine;
use crate::tensor::Pcg64;
use anyhow::Result;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Router configuration.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    pub artifacts_dir: PathBuf,
    pub model: String,
    pub batch_size: usize,
    pub workers: usize,
    pub options: SampleOptions,
}

/// Running worker fleet.
pub struct Router {
    pub batcher: Batcher,
    pub registry: Registry,
    stop: Arc<AtomicBool>,
    workers: Vec<JoinHandle<()>>,
}

impl Router {
    /// Spawn `cfg.workers` worker threads. Each validates its engine before
    /// the router returns (fail-fast on bad artifacts).
    pub fn start(cfg: RouterConfig, batcher: Batcher, registry: Registry) -> Result<Self> {
        let stop = Arc::new(AtomicBool::new(false));
        let mut workers = Vec::with_capacity(cfg.workers);
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<()>>();

        for widx in 0..cfg.workers.max(1) {
            let cfg = cfg.clone();
            let batcher = batcher.clone();
            let registry = registry.clone();
            let stop = stop.clone();
            let ready = ready_tx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("sjd-worker-{widx}"))
                    .spawn(move || worker_main(widx, cfg, batcher, registry, stop, ready))
                    .expect("spawn worker"),
            );
        }
        drop(ready_tx);
        for _ in 0..cfg.workers.max(1) {
            ready_rx.recv().expect("worker startup signal")?;
        }
        Ok(Router { batcher, registry, stop, workers })
    }

    /// Stop workers after the queue drains.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.batcher.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_main(
    widx: usize,
    cfg: RouterConfig,
    batcher: Batcher,
    registry: Registry,
    stop: Arc<AtomicBool>,
    ready: std::sync::mpsc::Sender<Result<()>>,
) {
    // Build the thread-pinned engine + sampler; report readiness.
    let engine = match Engine::new(&cfg.artifacts_dir) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let sampler = match Sampler::new(&engine, &cfg.model, cfg.batch_size) {
        Ok(s) => s,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let _ = ready.send(Ok(()));

    let lat = registry.histogram("sjd_request_latency");
    let batch_fill = registry.histogram("sjd_batch_fill");
    let images = registry.counter("sjd_images_generated");
    let batches = registry.counter("sjd_batches_processed");
    let errors = registry.counter("sjd_worker_errors");
    let inflight = registry.gauge("sjd_batches_inflight");

    while !stop.load(Ordering::SeqCst) {
        let Some(batch) = batcher.next_batch() else { break };
        inflight.add(1);
        batch_fill.record(batch.slots.len() as u64);
        // Derive the batch RNG from the first slot's seed so identical
        // requests reproduce identical images regardless of worker.
        let seed = batch.slots.first().map(|s| s.seed).unwrap_or(0);
        let mut rng = Pcg64::seed_stream(seed, widx as u64 + 1);
        match sampler.sample_images(&cfg.options, &mut rng) {
            Ok((imgs, _trace)) => {
                for (slot, img) in batch.slots.iter().zip(imgs.into_iter()) {
                    lat.record_duration(slot.enqueued.elapsed());
                    slot.done.put(img);
                    images.inc();
                }
                batches.inc();
            }
            Err(e) => {
                errors.inc();
                log::error!("worker {widx} sample failed: {e:#}");
                // Complete slots with a zero image so clients unblock.
                if let Some([h, w, c]) = sampler.meta.image_hwc {
                    for slot in &batch.slots {
                        slot.done.put(crate::tensor::Tensor::zeros(&[h, w, c]));
                    }
                }
            }
        }
        inflight.add(-1);
        let _ = Instant::now();
    }
}
