//! Multi-device placement + replica-tier correctness harness.
//!
//! The contract under test (ISSUE 10 / ROADMAP "multi-device"): sharding
//! the stage graph across device ordinals and fanning waves out across
//! replicas changes *where* a decode runs — never *what* it computes.
//! Per-slot RNG streams are derived from request seeds, so at τ = 0 every
//! image must be bit-identical to its solo serial decode under **every**
//! span×device×replica placement. The cross-span handoff cost model must
//! also stay truthful: exactly one host sync per wave per span boundary,
//! charged on `sjd_handoff_syncs` and visible in the per-ordinal mock
//! ledgers.
//!
//! Three tiers:
//! * a placement sweep (devices × replicas) holding every output to the
//!   solo oracle while proving each mapped ordinal actually decoded,
//! * an exact handoff-sync count over the raw `DecodePipeline`, and
//! * a least-loaded dispatch check: a slow replica must receive fewer
//!   waves than its fast peer, with outputs still bit-exact.

use sjd::coordinator::batcher::Batcher;
use sjd::coordinator::pipeline::{DecodePipeline, PipelineConfig, PipelineJob};
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::router::{Router, RouterConfig};
use sjd::coordinator::sampler::{SampleOptions, Sampler};
use sjd::metrics::Registry;
use sjd::runtime::HostTensor;
use sjd::testkit::mockflow::{MockLedger, MockServeBackend};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Flow blocks in `MockFlow::standard()` — the stage count when
/// `stage_threads: 0` asks for one thread per block.
const STAGES: usize = 4;

/// τ = 0 decode options: full exactness sweep, bit-comparable everywhere.
fn opts() -> SampleOptions {
    let mut o = SampleOptions { policy: DecodePolicy::UniformJacobi, ..Default::default() };
    o.jacobi.tau = 0.0;
    o
}

/// The ground truth each request is held to: a bucket-1 solo decode of the
/// same seed on a fresh single-device backend — no batching, no placement.
fn solo_reference(seed: u64) -> Vec<f32> {
    let be = MockServeBackend::new(&[1, 2, 4], Duration::ZERO, MockLedger::new());
    let sampler = Sampler::new(&be, "mock", 1).expect("solo sampler");
    let z = sampler.sample_prior_slots(&[seed]);
    let out = sampler.decode_tokens(z, &opts()).expect("solo decode");
    sampler.unpatchify(&out.tokens).expect("solo unpatchify")[0].data().to_vec()
}

#[test]
fn tau0_bit_exact_across_span_device_replica_placements() {
    // Placement sweep: (devices, replicas) over the pipelined router. Per
    // configuration, every delivered image must equal its solo decode, the
    // ordinals named by `device_placement(STAGES, devices)` must all have
    // decoded (their per-ordinal ledgers saw jstep calls), no ordinal
    // beyond the placement may have been touched, and every span boundary
    // must have charged the handoff counter (3 boundaries per wave at
    // 4 stages, so the total is a positive multiple of 3).
    let seeds: Vec<u64> = (0..10).collect();
    let want: Vec<Vec<f32>> = seeds.iter().map(|&s| solo_reference(s)).collect();

    for (devices, replicas) in [(1usize, 1usize), (2, 1), (3, 1), (2, 2)] {
        let registry = Registry::new();
        let batcher = Batcher::new(4, Duration::from_millis(2));
        // One ledger per *ordinal* (shared across replicas): the placement
        // evidence is "this device decoded", not "this replica decoded".
        let ledgers: Vec<Arc<MockLedger>> = (0..STAGES).map(|_| MockLedger::new()).collect();
        let lgs = ledgers.clone();
        let router = Router::start_with_devices(
            RouterConfig {
                artifacts_dir: "unused-by-mock".into(),
                model: "mock".into(),
                buckets: Vec::new(),
                workers: 1,
                options: opts(),
                pipeline_depth: 2,
                stage_threads: 0,
                refill: false,
                tuner: None,
                warm_cap: 0,
                governor: None,
                fault: Default::default(),
                replicas,
                devices,
            },
            batcher.clone(),
            registry.clone(),
            move |_widx, ordinal| {
                Ok(MockServeBackend::new(&[1, 2, 4], Duration::ZERO, lgs[ordinal].clone())
                    .on_ordinal(ordinal))
            },
        )
        .expect("router");

        let handles: Vec<_> =
            seeds.iter().map(|&s| batcher.submit_slot(s, s).expect("submit")).collect();
        for (i, h) in handles.iter().enumerate() {
            let img =
                h.done.wait_timeout(Duration::from_secs(30)).expect("resolves").expect("image");
            assert_eq!(
                img.data(),
                &want[i][..],
                "devices={devices} replicas={replicas}: seed {i} must be bit-exact with solo"
            );
        }
        router.shutdown();

        // Placement proof: exactly the mapped ordinals decoded. The
        // geometry probe (`factory(_, 0)`) never decodes, so an untouched
        // ledger really means "no stage ran here".
        let mapped = devices.clamp(1, STAGES);
        for (ord, ledger) in ledgers.iter().enumerate() {
            let jsteps = ledger.count_containing("_jstep");
            if ord < mapped {
                assert!(
                    jsteps > 0,
                    "devices={devices} replicas={replicas}: ordinal {ord} was placed a span \
                     but never decoded"
                );
            } else {
                assert_eq!(
                    jsteps, 0,
                    "devices={devices} replicas={replicas}: ordinal {ord} is outside the \
                     placement but decoded anyway"
                );
            }
        }
        let handoffs = registry.counter("sjd_handoff_syncs").get();
        assert!(
            handoffs > 0 && handoffs % (STAGES as u64 - 1) == 0,
            "devices={devices} replicas={replicas}: handoffs ({handoffs}) must be one per \
             wave per span boundary ({} boundaries)",
            STAGES - 1
        );
    }
}

#[test]
fn exactly_one_handoff_sync_per_span_boundary() {
    // Raw `DecodePipeline` (one submitted job = one wave, no batcher
    // timing) so the handoff count is exact: J jobs × (STAGES − 1)
    // boundaries. Run single-device and dual-device; tokens must match
    // bit-for-bit and both runs must charge the identical handoff bill —
    // placement moves spans across ordinals without adding syncs.
    const JOBS: u64 = 5;
    let run = |devices: usize| -> (BTreeMap<u64, HostTensor>, u64, Vec<Arc<MockLedger>>) {
        let registry = Registry::new();
        let ledgers: Vec<Arc<MockLedger>> = (0..STAGES).map(|_| MockLedger::new()).collect();
        let lgs = ledgers.clone();
        let cfg = PipelineConfig {
            depth: 2,
            stage_threads: 0,
            warm_cap: 0,
            devices,
            ..Default::default()
        };
        let pipeline = DecodePipeline::start("mock", &[2], cfg, registry.clone(), move |ord| {
            Ok(MockServeBackend::new(&[2], Duration::ZERO, lgs[ord].clone()).on_ordinal(ord))
        })
        .expect("pipeline");
        let results: Arc<Mutex<BTreeMap<u64, HostTensor>>> = Arc::new(Mutex::new(BTreeMap::new()));
        for seed in 0..JOBS {
            let results = results.clone();
            let job = PipelineJob {
                seeds: vec![seed, seed + 100],
                opts: opts(),
                done: Box::new(move |res| {
                    let (_imgs, out) = res.expect("pipeline decode");
                    results.lock().unwrap().insert(seed, out.tokens);
                }),
            };
            assert!(pipeline.submit(job).is_ok(), "pipeline rejected a submission");
        }
        pipeline.shutdown();
        let tokens = Arc::try_unwrap(results).ok().expect("callbacks done").into_inner().unwrap();
        assert_eq!(tokens.len(), JOBS as usize, "every job must complete");
        (tokens, registry.counter("sjd_handoff_syncs").get(), ledgers)
    };

    let (solo_tokens, solo_handoffs, _) = run(1);
    let (dual_tokens, dual_handoffs, dual_ledgers) = run(2);

    assert_eq!(solo_tokens, dual_tokens, "dual-device τ=0 tokens diverged from single-device");
    let expect = JOBS * (STAGES as u64 - 1);
    assert_eq!(solo_handoffs, expect, "single-device: one handoff per wave per boundary");
    assert_eq!(dual_handoffs, expect, "dual-device: placement must not add handoff syncs");

    // Per-ordinal ledger evidence of the latent crossing hosts: with
    // placement [0, 0, 1, 1], stages 0–1 forward from ordinal 0 and stage 2
    // forwards from ordinal 1 (stage 3's sync is the output, same series),
    // so both ordinals record rank-3 host syncs at least once per job.
    for ord in 0..2 {
        assert!(
            dual_ledgers[ord].count(&format!("host_sync_latent_ord{ord}")) >= JOBS as usize,
            "ordinal {ord} must sync its span output to host once per wave"
        );
    }
}

#[test]
fn least_loaded_dispatch_skews_waves_away_from_slow_replica() {
    // Two pipelined replicas behind one batcher, one decoding ~40× slower
    // per jstep. The dispatch board gates each replica's batcher pulls on
    // being least-loaded (in-flight-weighted), so the wave stream must skew
    // to the fast replica — round-robin would split 50/50 and every second
    // request would eat the slow replica's latency. Outputs stay bit-exact:
    // routing is placement, not math.
    let seeds: Vec<u64> = (0..24).collect();
    let want: Vec<Vec<f32>> = seeds.iter().map(|&s| solo_reference(s)).collect();

    let registry = Registry::new();
    // Bucket-1 waves: one request per wave, so per-replica jstep counts
    // read directly as "waves routed here".
    let batcher = Batcher::new(1, Duration::from_millis(1));
    let ledgers: Vec<Arc<MockLedger>> = (0..2).map(|_| MockLedger::new()).collect();
    let lgs = ledgers.clone();
    let router = Router::start_with_devices(
        RouterConfig {
            artifacts_dir: "unused-by-mock".into(),
            model: "mock".into(),
            buckets: Vec::new(),
            workers: 1,
            options: opts(),
            pipeline_depth: 2,
            stage_threads: 0,
            refill: false,
            tuner: None,
            warm_cap: 0,
            governor: None,
            fault: Default::default(),
            replicas: 2,
            devices: 1,
        },
        batcher.clone(),
        registry.clone(),
        move |widx, _ordinal| {
            let delay =
                if widx == 0 { Duration::from_millis(4) } else { Duration::from_micros(100) };
            Ok(MockServeBackend::new(&[1], delay, lgs[widx].clone()))
        },
    )
    .expect("router");

    let handles: Vec<_> =
        seeds.iter().map(|&s| batcher.submit_slot(s, s).expect("submit")).collect();
    for (i, h) in handles.iter().enumerate() {
        let img = h.done.wait_timeout(Duration::from_secs(60)).expect("resolves").expect("image");
        assert_eq!(
            img.data(),
            &want[i][..],
            "seed {i}: replica routing must not change a single output bit"
        );
    }
    router.shutdown();

    let slow = ledgers[0].count_containing("_jstep");
    let fast = ledgers[1].count_containing("_jstep");
    assert!(
        fast > slow,
        "least-loaded dispatch must skew waves to the fast replica (fast {fast} jsteps vs \
         slow {slow})"
    );
    // Both inflight gauges were registered (and have drained back to 0).
    for r in 0..2 {
        assert_eq!(
            registry.gauge(&format!("sjd_replica_{r}_inflight")).get(),
            0,
            "replica {r} in-flight accounting must balance to zero after drain"
        );
    }
}
