//! Fault-tolerant execution layer: retry, circuit breaker, watchdog.
//!
//! Sits between the dispatch loops (router workers, pipeline stages) and
//! any [`Backend`], turning the typed fault taxonomy of
//! [`runtime::fault`](crate::runtime::FaultClass) into recovery behavior:
//!
//! * **Retry** — [`FaultTolerantBackend`] retries `Transient` faults of
//!   `call_v`/`to_device`/`to_host` with capped exponential backoff,
//!   budgeted against the live slot deadline (a retry that could not
//!   finish before the wave's earliest deadline is not attempted), counted
//!   in `sjd_backend_retries`. Retrying is *bit-safe* at τ = 0: by Prop
//!   3.2 the Jacobi fixed point is independent of the starting iterate, so
//!   a re-dispatched step converges to the same output.
//! * **Circuit breaker** — `quarantine_after` *consecutive* `Poison`
//!   failures of one artifact quarantine it for `probe_interval`
//!   (`sjd_artifact_quarantined`). While quarantined the wrapper's
//!   [`has_artifact`](Backend::has_artifact) answers `false`, which the
//!   sampler's `effective_block_mode` consults live on every block decode
//!   — so optional-role artifacts (`jstep_fuse`, `jstep_win`,
//!   `jstep_win_fuse`, `init_proj`, `slot_gather`) reroute through the
//!   existing degradation chain (gs_fuse → gs → jacobi) with zero sampler
//!   changes. After the probe interval one probe call is let through: a
//!   success closes the breaker, another poison re-quarantines. Required
//!   artifacts (base `jstep`/`seqstep`/`reverse`) have no chain below them;
//!   their quarantine fails dispatches fast instead of re-executing a
//!   known-poisoned program.
//! * **Watchdog** — a [`Watchdog`] monitor thread arms one [`WatchGuard`]
//!   per dispatch (wave granularity: synchronous backend calls cannot be
//!   aborted mid-flight). If the guard's timeout lapses before the
//!   dispatch returns, the wave's slots resolve `Err` via `put_once`
//!   (exactly-once against the worker's own completion) and the guard is
//!   marked fired; the dispatcher checks [`WatchGuard::fired`] on return,
//!   discards the late result, and treats the episode as `DeviceLost` so
//!   supervision replaces the engine. A dispatch that *never* returns
//!   wedges its thread, but its requests are answered and the fleet health
//!   endpoint shows the loss.
//!
//! Worker supervision itself (respawn budgets, panic accounting) lives in
//! [`router`](crate::coordinator::router); this module provides the pieces
//! it composes.

use crate::coordinator::batcher::SlotResult;
use crate::exec::OneShot;
use crate::metrics::{Counter, Registry};
use crate::runtime::{classify, Backend, FaultClass, HostTensor, ModelMeta, Value};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Recovery knobs, one copy per worker (`serve --retry-budget
/// --quarantine-after --worker-restarts`).
#[derive(Clone, Debug)]
pub struct FaultPolicy {
    /// Max retries of one dispatch after a `Transient` fault (0 disables).
    pub retry_budget: usize,
    /// First-retry backoff; doubles per attempt up to [`backoff_cap`](Self::backoff_cap).
    pub backoff_base: Duration,
    /// Ceiling on a single backoff sleep.
    pub backoff_cap: Duration,
    /// Consecutive `Poison` failures of one artifact that trip its breaker
    /// (0 disables quarantine).
    pub quarantine_after: usize,
    /// How long a tripped artifact stays quarantined before one probe call
    /// is allowed through.
    pub probe_interval: Duration,
    /// Per-dispatch watchdog timeout (`None` disables the watchdog).
    pub watchdog: Option<Duration>,
    /// Times a panicked/device-lost worker is respawned with a fresh
    /// engine before it is retired (enforced by the router's supervisor).
    pub worker_restarts: usize,
}

impl Default for FaultPolicy {
    fn default() -> Self {
        FaultPolicy {
            retry_budget: 3,
            backoff_base: Duration::from_millis(5),
            backoff_cap: Duration::from_millis(100),
            quarantine_after: 3,
            probe_interval: Duration::from_secs(2),
            watchdog: Some(Duration::from_secs(30)),
            worker_restarts: 2,
        }
    }
}

/// Shared, updatable view of "the earliest deadline among the slots this
/// backend is currently decoding". Workers set it per wave/chunk; the
/// fault-tolerant wrapper reads it to decide whether a retry (backoff +
/// re-dispatch) can still meet the wave's promise.
#[derive(Clone, Default)]
pub struct DeadlineCell {
    inner: Arc<Mutex<Option<Instant>>>,
}

impl DeadlineCell {
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the active deadline for the in-flight wave (`None` = none).
    pub fn set(&self, d: Option<Instant>) {
        *self.inner.lock().unwrap() = d;
    }

    pub fn clear(&self) {
        self.set(None);
    }

    /// Time left before the active deadline (`None` = unbounded).
    pub fn remaining(&self) -> Option<Duration> {
        self.inner
            .lock()
            .unwrap()
            .map(|d| d.saturating_duration_since(Instant::now()))
    }
}

/// Per-artifact circuit-breaker state.
#[derive(Default)]
struct Breaker {
    /// Consecutive `Poison` failures since the last success.
    consecutive: usize,
    /// While set and in the future: quarantined. A call arriving after the
    /// instant passed is the probe.
    quarantined_until: Option<Instant>,
}

/// [`Backend`] wrapper adding retry, breaker-quarantine and fault
/// accounting. One per engine (it is as thread-pinned as the engine it
/// wraps); the [`DeadlineCell`] is the worker's channel for deadline
/// budgets, shareable across the worker's dispatch loop.
pub struct FaultTolerantBackend<B> {
    inner: B,
    policy: FaultPolicy,
    deadline: DeadlineCell,
    breakers: Mutex<HashMap<String, Breaker>>,
    m_retries: Arc<Counter>,
    m_quarantined: Arc<Counter>,
}

impl<B: Backend> FaultTolerantBackend<B> {
    pub fn new(inner: B, policy: FaultPolicy, registry: &Registry) -> Self {
        FaultTolerantBackend {
            inner,
            policy,
            deadline: DeadlineCell::new(),
            breakers: Mutex::new(HashMap::new()),
            m_retries: registry.counter("sjd_backend_retries"),
            m_quarantined: registry.counter("sjd_artifact_quarantined"),
        }
    }

    /// The deadline cell dispatch loops should update per wave.
    pub fn deadline_cell(&self) -> DeadlineCell {
        self.deadline.clone()
    }

    pub fn inner(&self) -> &B {
        &self.inner
    }

    /// Whether `name` is currently quarantined (probe window not yet open).
    pub fn quarantined(&self, name: &str) -> bool {
        let breakers = self.breakers.lock().unwrap();
        breakers
            .get(name)
            .and_then(|b| b.quarantined_until)
            .is_some_and(|until| Instant::now() < until)
    }

    /// Record a dispatch success: the artifact's breaker closes fully.
    fn note_success(&self, name: &str) {
        let mut breakers = self.breakers.lock().unwrap();
        if let Some(b) = breakers.get_mut(name) {
            b.consecutive = 0;
            b.quarantined_until = None;
        }
    }

    /// Record a `Poison` failure; trips the breaker at the policy
    /// threshold. Returns whether this failure newly quarantined the
    /// artifact.
    fn note_poison(&self, name: &str) -> bool {
        if self.policy.quarantine_after == 0 {
            return false;
        }
        let mut breakers = self.breakers.lock().unwrap();
        let b = breakers.entry(name.to_string()).or_default();
        b.consecutive += 1;
        if b.consecutive >= self.policy.quarantine_after {
            // (Re-)quarantine: also the probe-failed path, where
            // `consecutive` is already at/over threshold.
            let was_open = b
                .quarantined_until
                .is_some_and(|until| Instant::now() < until);
            b.quarantined_until = Some(Instant::now() + self.policy.probe_interval);
            if !was_open {
                self.m_quarantined.inc();
                log::warn!(
                    "artifact '{name}' quarantined after {} consecutive poison faults \
                     (probe in {:?})",
                    b.consecutive,
                    self.policy.probe_interval
                );
                return true;
            }
        }
        false
    }

    /// Whether a retry sleeping `backoff` can still matter: either there
    /// is no active deadline, or enough budget remains to back off *and*
    /// plausibly re-run.
    fn retry_fits_deadline(&self, backoff: Duration) -> bool {
        match self.deadline.remaining() {
            None => true,
            Some(rem) => rem > backoff * 2,
        }
    }

    /// Run `op` under the transient-retry loop. `what` names the operation
    /// for logs; `artifact` keys breaker accounting (transfers pass `None`
    /// — there is no program to quarantine).
    fn with_retries<T>(
        &self,
        what: &str,
        artifact: Option<&str>,
        mut op: impl FnMut() -> anyhow::Result<T>,
    ) -> anyhow::Result<T> {
        let mut backoff = self.policy.backoff_base;
        let mut attempt = 0usize;
        loop {
            match op() {
                Ok(v) => {
                    if let Some(name) = artifact {
                        self.note_success(name);
                    }
                    return Ok(v);
                }
                Err(e) => match classify(&e) {
                    FaultClass::Transient
                        if attempt < self.policy.retry_budget
                            && self.retry_fits_deadline(backoff) =>
                    {
                        attempt += 1;
                        self.m_retries.inc();
                        log::warn!(
                            "transient fault in {what} (attempt {attempt}/{}): {e:#}; \
                             retrying in {backoff:?}",
                            self.policy.retry_budget
                        );
                        std::thread::sleep(backoff);
                        backoff = (backoff * 2).min(self.policy.backoff_cap);
                    }
                    FaultClass::Transient => {
                        return Err(e.context(format!(
                            "{what}: transient fault persisted past the retry budget \
                             ({attempt}/{})",
                            self.policy.retry_budget
                        )));
                    }
                    FaultClass::DeviceLost => return Err(e),
                    FaultClass::Poison => {
                        if let Some(name) = artifact {
                            self.note_poison(name);
                        }
                        return Err(e);
                    }
                },
            }
        }
    }
}

impl<B: Backend> Backend for FaultTolerantBackend<B> {
    fn call_v(&self, name: &str, inputs: &[Value]) -> anyhow::Result<Vec<Value>> {
        // Fail fast on a quarantined artifact instead of re-executing a
        // known-poisoned program. Dispatch loops normally never get here —
        // `has_artifact` already steered `effective_block_mode` away — so
        // this covers required roles with no degradation chain below them.
        if self.quarantined(name) {
            return Err(crate::runtime::Fault::poison(name)
                .context(format!("artifact '{name}' is quarantined")));
        }
        self.with_retries(name, Some(name), || self.inner.call_v(name, inputs))
    }

    fn model_meta(&self, model: &str) -> anyhow::Result<ModelMeta> {
        self.inner.model_meta(model)
    }

    fn to_device(&self, t: &HostTensor) -> anyhow::Result<Value> {
        self.with_retries("to_device", None, || self.inner.to_device(t))
    }

    fn to_host(&self, v: Value) -> anyhow::Result<HostTensor> {
        // `to_host` consumes its value, so the retry closure re-clones.
        self.with_retries("to_host", None, || self.inner.to_host(v.clone()))
    }

    fn device_ordinal(&self) -> usize {
        self.inner.device_ordinal()
    }

    fn to_ordinal(&self, v: &Value, ordinal: usize) -> anyhow::Result<Value> {
        self.with_retries("to_ordinal", None, || self.inner.to_ordinal(v, ordinal))
    }

    /// Quarantine seam: a quarantined artifact reads as absent, which the
    /// sampler's live `effective_block_mode` lookup turns into a
    /// degradation-chain reroute (gs_fuse → gs → jacobi) on the very next
    /// block decode. Once the probe window opens the artifact reappears.
    fn has_artifact(&self, name: &str) -> bool {
        !self.quarantined(name) && self.inner.has_artifact(name)
    }
}

/// Message prefix of a slot resolved by the dispatch watchdog.
pub const WATCHDOG_FIRED_MSG: &str = "dispatch watchdog fired";

/// Best-effort human-readable panic payload (panics carry `&str` or
/// `String` in practice). Shared by the router supervisor and the pipeline
/// stage guards.
pub(crate) fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

struct WatchEntry {
    id: u64,
    deadline: Instant,
    /// Completion channels of the wave's slots; resolved `Err` via
    /// `put_once` when the timer fires (exactly-once vs the dispatcher).
    failers: Vec<OneShot<SlotResult>>,
    fired: Arc<AtomicBool>,
}

#[derive(Default)]
struct WatchState {
    entries: Vec<WatchEntry>,
    shutdown: bool,
}

struct WatchShared {
    state: Mutex<WatchState>,
    cv: Condvar,
}

/// Monitor for hung dispatches: one background thread, any number of
/// concurrently armed [`WatchGuard`]s (one per in-flight wave dispatch).
pub struct Watchdog {
    shared: Arc<WatchShared>,
    next_id: AtomicU64,
    m_fired: Arc<Counter>,
    monitor: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Watchdog {
    pub fn new(registry: &Registry) -> Arc<Self> {
        let shared = Arc::new(WatchShared {
            state: Mutex::new(WatchState::default()),
            cv: Condvar::new(),
        });
        let m_fired = registry.counter("sjd_watchdog_fired");
        let monitor = {
            let shared = shared.clone();
            let m_fired = m_fired.clone();
            std::thread::Builder::new()
                .name("sjd-watchdog".into())
                .spawn(move || monitor_main(shared, m_fired))
                .expect("spawn watchdog monitor")
        };
        Arc::new(Watchdog {
            shared,
            next_id: AtomicU64::new(1),
            m_fired,
            monitor: Mutex::new(Some(monitor)),
        })
    }

    /// Arm a guard for one dispatch: if it is still armed after `timeout`,
    /// every failer resolves `Err` and [`WatchGuard::fired`] turns true.
    pub fn guard(
        self: &Arc<Self>,
        timeout: Duration,
        failers: Vec<OneShot<SlotResult>>,
    ) -> WatchGuard {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let fired = Arc::new(AtomicBool::new(false));
        {
            let mut st = self.shared.state.lock().unwrap();
            st.entries.push(WatchEntry {
                id,
                deadline: Instant::now() + timeout,
                failers,
                fired: fired.clone(),
            });
        }
        self.shared.cv.notify_all();
        WatchGuard { dog: self.clone(), id, fired }
    }

    /// Total dispatches the monitor has failed.
    pub fn fired_total(&self) -> u64 {
        self.m_fired.get()
    }

    /// Stop the monitor thread. Armed guards stop being enforced; pending
    /// waves still resolve through the normal dispatcher paths (or the
    /// slot completion guard).
    pub fn shutdown(&self) {
        let handle = {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.cv.notify_all();
            self.monitor.lock().unwrap().take()
        };
        if let Some(h) = handle {
            let _ = h.join();
        }
    }
}

impl Drop for Watchdog {
    /// Dispatch loops shut the watchdog down explicitly on their exit
    /// funnels; this covers the unwind path (a worker panic drops its
    /// `Arc<Watchdog>` mid-flight) so the monitor thread never leaks.
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn monitor_main(shared: Arc<WatchShared>, m_fired: Arc<Counter>) {
    let mut st = shared.state.lock().unwrap();
    loop {
        if st.shutdown {
            return;
        }
        let now = Instant::now();
        // Fire everything due, keep the rest.
        let mut due = Vec::new();
        st.entries.retain_mut(|e| {
            if e.deadline <= now {
                due.push((std::mem::take(&mut e.failers), e.fired.clone()));
                false
            } else {
                true
            }
        });
        let next = st.entries.iter().map(|e| e.deadline).min();
        if !due.is_empty() {
            drop(st);
            for (failers, fired) in due {
                fired.store(true, Ordering::SeqCst);
                m_fired.inc();
                log::error!(
                    "dispatch watchdog fired: failing a hung wave of {} slot(s)",
                    failers.len()
                );
                for f in failers {
                    f.put_once(Err(format!("{WATCHDOG_FIRED_MSG} (dispatch hung)")));
                }
            }
            st = shared.state.lock().unwrap();
            continue;
        }
        st = match next {
            Some(deadline) => {
                let wait = deadline.saturating_duration_since(now);
                shared.cv.wait_timeout(st, wait).unwrap().0
            }
            None => shared.cv.wait(st).unwrap(),
        };
    }
}

/// RAII handle for one watched dispatch; disarm by dropping.
pub struct WatchGuard {
    dog: Arc<Watchdog>,
    id: u64,
    fired: Arc<AtomicBool>,
}

impl WatchGuard {
    /// Whether the monitor fired (and resolved the wave's slots) before
    /// the dispatch returned — the dispatcher must then discard its late
    /// result and treat the episode as `DeviceLost`.
    pub fn fired(&self) -> bool {
        self.fired.load(Ordering::SeqCst)
    }
}

impl Drop for WatchGuard {
    fn drop(&mut self) {
        let mut st = self.dog.shared.state.lock().unwrap();
        st.entries.retain(|e| e.id != self.id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Fault;
    use std::sync::atomic::AtomicUsize;

    /// Backend failing the first `fail` calls of each artifact with the
    /// given class, then succeeding with an empty output.
    struct Flaky {
        fail: usize,
        class: FaultClass,
        calls: Mutex<HashMap<String, usize>>,
        total: AtomicUsize,
    }

    impl Flaky {
        fn new(fail: usize, class: FaultClass) -> Self {
            Flaky { fail, class, calls: Mutex::new(HashMap::new()), total: AtomicUsize::new(0) }
        }
    }

    impl Backend for Flaky {
        fn call_v(&self, name: &str, _inputs: &[Value]) -> anyhow::Result<Vec<Value>> {
            self.total.fetch_add(1, Ordering::SeqCst);
            let mut calls = self.calls.lock().unwrap();
            let n = calls.entry(name.to_string()).or_insert(0);
            *n += 1;
            if *n <= self.fail {
                return Err(Fault::new(self.class, name).context("injected"));
            }
            Ok(vec![])
        }

        fn model_meta(&self, _model: &str) -> anyhow::Result<ModelMeta> {
            anyhow::bail!("no meta")
        }

        fn has_artifact(&self, _name: &str) -> bool {
            true
        }
    }

    fn policy_fast() -> FaultPolicy {
        FaultPolicy {
            retry_budget: 3,
            backoff_base: Duration::from_micros(100),
            backoff_cap: Duration::from_millis(1),
            quarantine_after: 2,
            probe_interval: Duration::from_millis(30),
            watchdog: None,
            worker_restarts: 2,
        }
    }

    #[test]
    fn transient_faults_retry_within_budget() {
        let r = Registry::new();
        let ft = FaultTolerantBackend::new(Flaky::new(2, FaultClass::Transient), policy_fast(), &r);
        assert!(ft.call_v("m_jstep_b1", &[]).is_ok());
        assert_eq!(r.counter("sjd_backend_retries").get(), 2);
        // Budget exhausted: 3 retries cannot cover 4 failures.
        let ft = FaultTolerantBackend::new(Flaky::new(4, FaultClass::Transient), policy_fast(), &r);
        let err = ft.call_v("m_jstep_b1", &[]).unwrap_err();
        assert_eq!(classify(&err), FaultClass::Transient);
        assert!(format!("{err:#}").contains("retry budget"), "{err:#}");
    }

    #[test]
    fn deadline_budget_suppresses_retries() {
        let r = Registry::new();
        let ft = FaultTolerantBackend::new(Flaky::new(1, FaultClass::Transient), policy_fast(), &r);
        ft.deadline_cell().set(Some(Instant::now())); // already due: no room
        assert!(ft.call_v("m_jstep_b1", &[]).is_err());
        assert_eq!(r.counter("sjd_backend_retries").get(), 0);
        ft.deadline_cell().clear();
        assert!(ft.call_v("m_jstep_b1", &[]).is_ok()); // second call succeeds anyway
    }

    #[test]
    fn poison_streak_quarantines_and_probe_recovers() {
        let r = Registry::new();
        // Fails twice (= quarantine_after), then healthy.
        let ft = FaultTolerantBackend::new(Flaky::new(2, FaultClass::Poison), policy_fast(), &r);
        assert!(ft.has_artifact("m_jstep_fuse_b4"));
        assert!(ft.call_v("m_jstep_fuse_b4", &[]).is_err());
        assert!(!ft.quarantined("m_jstep_fuse_b4"), "one poison must not trip");
        assert!(ft.call_v("m_jstep_fuse_b4", &[]).is_err());
        assert!(ft.quarantined("m_jstep_fuse_b4"), "streak at threshold trips");
        assert!(!ft.has_artifact("m_jstep_fuse_b4"), "quarantined reads as absent");
        assert_eq!(r.counter("sjd_artifact_quarantined").get(), 1);
        // Other artifacts are untouched.
        assert!(ft.has_artifact("m_jstep_b4"));
        // Quarantined calls fail fast without reaching the backend.
        let before = ft.inner().total.load(Ordering::SeqCst);
        let err = ft.call_v("m_jstep_fuse_b4", &[]).unwrap_err();
        assert!(format!("{err:#}").contains("quarantined"), "{err:#}");
        assert_eq!(ft.inner().total.load(Ordering::SeqCst), before);
        // After the probe interval the artifact reappears and the probe
        // call (now healthy) closes the breaker for good.
        std::thread::sleep(Duration::from_millis(35));
        assert!(ft.has_artifact("m_jstep_fuse_b4"));
        assert!(ft.call_v("m_jstep_fuse_b4", &[]).is_ok());
        assert!(!ft.quarantined("m_jstep_fuse_b4"));
    }

    #[test]
    fn failed_probe_requarantines_without_recounting() {
        let r = Registry::new();
        // Poisoned forever: every probe fails and re-opens the breaker.
        let ft =
            FaultTolerantBackend::new(Flaky::new(usize::MAX, FaultClass::Poison), policy_fast(), &r);
        assert!(ft.call_v("m_gather_b2", &[]).is_err());
        assert!(ft.call_v("m_gather_b2", &[]).is_err());
        assert!(ft.quarantined("m_gather_b2"));
        assert_eq!(r.counter("sjd_artifact_quarantined").get(), 1);
        std::thread::sleep(Duration::from_millis(35));
        assert!(!ft.quarantined("m_gather_b2"), "probe window open");
        assert!(ft.call_v("m_gather_b2", &[]).is_err()); // failed probe
        assert!(ft.quarantined("m_gather_b2"), "failed probe re-quarantines");
        assert_eq!(
            r.counter("sjd_artifact_quarantined").get(),
            2,
            "a re-quarantine after an open probe window counts again"
        );
    }

    #[test]
    fn device_lost_is_never_retried() {
        let r = Registry::new();
        let ft =
            FaultTolerantBackend::new(Flaky::new(1, FaultClass::DeviceLost), policy_fast(), &r);
        let err = ft.call_v("m_jstep_b1", &[]).unwrap_err();
        assert_eq!(classify(&err), FaultClass::DeviceLost);
        assert_eq!(r.counter("sjd_backend_retries").get(), 0);
        assert_eq!(ft.inner().total.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn watchdog_fails_hung_wave_and_spares_fast_ones() {
        let r = Registry::new();
        let dog = Watchdog::new(&r);
        // Fast dispatch: guard dropped before the timeout, nothing fires.
        let fast: OneShot<SlotResult> = OneShot::new();
        {
            let _g = dog.guard(Duration::from_millis(50), vec![fast.clone()]);
        }
        // Hung dispatch: the guard stays armed past its timeout.
        let hung: OneShot<SlotResult> = OneShot::new();
        let g = dog.guard(Duration::from_millis(10), vec![hung.clone()]);
        let res = hung.wait_timeout(Duration::from_secs(2)).expect("watchdog resolves slot");
        assert!(res.unwrap_err().starts_with(WATCHDOG_FIRED_MSG));
        assert!(g.fired());
        assert!(!fast.filled(), "fast wave untouched");
        assert_eq!(r.counter("sjd_watchdog_fired").get(), 1);
        // Late worker result loses the race (exactly-once).
        assert!(!hung.put_once(Ok(crate::tensor::Tensor::zeros(&[1]))));
        dog.shutdown();
    }
}
