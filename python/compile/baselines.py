"""Table A6 comparators: a tiny DDPM (sampled with 20-step DDIM) and an
MMD-trained generator (FastGAN substitute — single forward pass, stable
training without an adversary; DESIGN.md §5).
"""

from typing import NamedTuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# DDPM / DDIM
# ---------------------------------------------------------------------------

class DdpmConfig(NamedTuple):
    name: str
    img_hw: int
    channels: int
    hidden: int
    timesteps: int
    dataset: str
    train_steps: int
    train_batch: int
    lr: float


def ddpm_schedule(cfg: DdpmConfig):
    """Linear beta schedule → (betas, alphas, alpha_bars)."""
    betas = jnp.linspace(1e-4, 0.02, cfg.timesteps)
    alphas = 1.0 - betas
    alpha_bars = jnp.cumprod(alphas)
    return betas, alphas, alpha_bars


def init_ddpm_params(key, cfg: DdpmConfig):
    c, h = cfg.channels, cfg.hidden
    keys = jax.random.split(key, 6)
    return {
        "c1": jax.random.normal(keys[0], (3, 3, c, h)) / jnp.sqrt(9 * c),
        "b1": jnp.zeros((h,)),
        "temb_w": jax.random.normal(keys[1], (32, h)) / jnp.sqrt(32),
        "temb_b": jnp.zeros((h,)),
        "c2": jax.random.normal(keys[2], (3, 3, h, h)) / jnp.sqrt(9 * h),
        "b2": jnp.zeros((h,)),
        "c3": jax.random.normal(keys[3], (3, 3, h, h)) / jnp.sqrt(9 * h),
        "b3": jnp.zeros((h,)),
        "c4": jnp.zeros((3, 3, h, c)),
        "b4": jnp.zeros((c,)),
    }


def _conv(x, w, b):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + b


def _time_embedding(t, dim=32):
    """Sinusoidal timestep embedding; t: () or (B,) i32."""
    t = jnp.asarray(t, jnp.float32)
    half = dim // 2
    freqs = jnp.exp(-jnp.log(1000.0) * jnp.arange(half) / half)
    ang = t[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def eps_model(params, x, t):
    """Predict noise ε from x_t. x: (B, H, W, C), t: i32 scalar."""
    emb = _time_embedding(t)  # (32,)
    temb = jax.nn.silu(emb @ params["temb_w"] + params["temb_b"])  # (hidden,)
    h = jax.nn.silu(_conv(x, params["c1"], params["b1"]) + temb[None, None, None, :])
    h = jax.nn.silu(_conv(h, params["c2"], params["b2"]))
    h = jax.nn.silu(_conv(h, params["c3"], params["b3"]))
    return _conv(h, params["c4"], params["b4"])


def ddpm_loss(params, cfg: DdpmConfig, x0, key):
    """Standard ε-prediction MSE at uniformly sampled timesteps."""
    _, _, abars = ddpm_schedule(cfg)
    kt, ke = jax.random.split(key)
    t = jax.random.randint(kt, (), 0, cfg.timesteps)
    eps = jax.random.normal(ke, x0.shape)
    ab = abars[t]
    xt = jnp.sqrt(ab) * x0 + jnp.sqrt(1 - ab) * eps
    pred = eps_model(params, xt, t)
    return jnp.mean((pred - eps) ** 2)


# ---------------------------------------------------------------------------
# MMD generator (FastGAN substitute)
# ---------------------------------------------------------------------------

class MmdGenConfig(NamedTuple):
    name: str
    img_hw: int
    channels: int
    z_dim: int
    hidden: int
    dataset: str
    train_steps: int
    train_batch: int
    lr: float


def init_gen_params(key, cfg: MmdGenConfig):
    s0 = cfg.img_hw // 4
    keys = jax.random.split(key, 4)
    return {
        "fc_w": jax.random.normal(keys[0], (cfg.z_dim, s0 * s0 * cfg.hidden)) / jnp.sqrt(cfg.z_dim),
        "fc_b": jnp.zeros((s0 * s0 * cfg.hidden,)),
        "c1": jax.random.normal(keys[1], (3, 3, cfg.hidden, cfg.hidden)) / jnp.sqrt(9 * cfg.hidden),
        "b1": jnp.zeros((cfg.hidden,)),
        "c2": jax.random.normal(keys[2], (3, 3, cfg.hidden, cfg.hidden // 2)) / jnp.sqrt(9 * cfg.hidden),
        "b2": jnp.zeros((cfg.hidden // 2,)),
        "c3": jax.random.normal(keys[3], (3, 3, cfg.hidden // 2, cfg.channels)) / jnp.sqrt(9 * cfg.hidden // 2),
        "b3": jnp.zeros((cfg.channels,)),
    }


def _upsample2(x):
    b, h, w, c = x.shape
    return jax.image.resize(x, (b, 2 * h, 2 * w, c), "nearest")


def generator(params, cfg: MmdGenConfig, z):
    """z (B, z_dim) → images (B, H, W, C) in [-1, 1]."""
    s0 = cfg.img_hw // 4
    h = jax.nn.silu(z @ params["fc_w"] + params["fc_b"])
    h = h.reshape(-1, s0, s0, cfg.hidden)
    h = _upsample2(h)
    h = jax.nn.silu(_conv(h, params["c1"], params["b1"]))
    h = _upsample2(h)
    h = jax.nn.silu(_conv(h, params["c2"], params["b2"]))
    return jnp.tanh(_conv(h, params["c3"], params["b3"]))


def mmd_loss(params, cfg: MmdGenConfig, real, key):
    """RBF-kernel MMD² between generated and real batches (pixel space,
    multi-bandwidth)."""
    z = jax.random.normal(key, (real.shape[0], cfg.z_dim))
    fake = generator(params, cfg, z)
    x = real.reshape(real.shape[0], -1)
    y = fake.reshape(fake.shape[0], -1)

    def pdist2(a, b):
        return jnp.sum(a * a, 1)[:, None] + jnp.sum(b * b, 1)[None, :] - 2 * a @ b.T

    dxx, dyy, dxy = pdist2(x, x), pdist2(y, y), pdist2(x, y)
    loss = 0.0
    for bw in (10.0, 50.0, 200.0):
        kxx = jnp.exp(-dxx / bw)
        kyy = jnp.exp(-dyy / bw)
        kxy = jnp.exp(-dxy / bw)
        loss = loss + kxx.mean() + kyy.mean() - 2 * kxy.mean()
    return loss
