"""Synthetic datasets (CIFAR-10 / CIFAR-100 / AFHQ / binary-MNIST stand-ins).

The paper's method depends on *trained autoregressive flows over spatially
local image data*, not on photographic content (DESIGN.md §5), so each
dataset is a procedural generator with a fixed class structure:

* ``synth10``  — 16×16 RGB, 10 classes of sinusoid/checker/blob textures.
* ``synth100`` — same generator family, 100 parameter tuples.
* ``synthafhq``— 32×32 RGB "blob faces" (background gradient + eyes + mouth),
  the large-L regime where the paper's UJD-loses/SJD-wins asymmetry shows.
* ``digits``   — 14×14 binary glyphs (5×7 bitmap font upscaled with jitter).

All values are in [-1, 1]. Generators are deterministic given (seed, index).
"""

import numpy as np

_FONT = {
    0: ["111", "101", "101", "101", "111"],
    1: ["010", "110", "010", "010", "111"],
    2: ["111", "001", "111", "100", "111"],
    3: ["111", "001", "111", "001", "111"],
    4: ["101", "101", "111", "001", "001"],
    5: ["111", "100", "111", "001", "111"],
    6: ["111", "100", "111", "101", "111"],
    7: ["111", "001", "010", "010", "010"],
    8: ["111", "101", "111", "101", "111"],
    9: ["111", "101", "111", "001", "111"],
}


def _class_params(rng: np.random.Generator, n_classes: int):
    """Random-but-fixed per-class texture parameters."""
    return [
        {
            "freq": rng.uniform(0.3, 1.8, size=2),
            "phase": rng.uniform(0, 2 * np.pi, size=3),
            "amp": rng.uniform(0.3, 0.9, size=3),
            "kind": int(rng.integers(0, 4)),
            "blob": rng.uniform(0.2, 0.8, size=2),
            "blob_sigma": rng.uniform(1.5, 4.0),
            "hue": rng.uniform(-0.6, 0.6, size=3),
        }
        for _ in range(n_classes)
    ]


class SynthImages:
    """Procedural texture dataset."""

    def __init__(self, size: int, n_classes: int, seed: int = 0, noise: float = 0.08):
        self.size = size
        self.n_classes = n_classes
        self.noise = noise
        self.params = _class_params(np.random.default_rng(seed), n_classes)

    def batch(self, n: int, seed: int) -> np.ndarray:
        """(n, size, size, 3) f32 in [-1, 1]."""
        rng = np.random.default_rng(seed)
        s = self.size
        yy, xx = np.mgrid[0:s, 0:s].astype(np.float32)
        out = np.zeros((n, s, s, 3), np.float32)
        classes = rng.integers(0, self.n_classes, size=n)
        for i in range(n):
            p = self.params[classes[i]]
            ph = rng.uniform(0, 2 * np.pi)
            fx, fy = p["freq"] * (1.0 + 0.1 * rng.standard_normal(2))
            if p["kind"] == 0:      # diagonal sinusoid
                field = np.sin(fx * xx + fy * yy + ph)
            elif p["kind"] == 1:    # checker
                field = np.sign(np.sin(fx * xx + ph) * np.sin(fy * yy + ph))
            elif p["kind"] == 2:    # rings
                cx, cy = s * p["blob"]
                r = np.sqrt((xx - cx) ** 2 + (yy - cy) ** 2)
                field = np.sin(fx * r + ph)
            else:                   # stripes
                field = np.sin(fx * xx + ph)
            cx, cy = s * p["blob"]
            blob = np.exp(-((xx - cx) ** 2 + (yy - cy) ** 2) / (2 * p["blob_sigma"] ** 2))
            for c in range(3):
                img = p["amp"][c] * field * np.cos(p["phase"][c]) + 0.6 * blob + p["hue"][c]
                out[i, :, :, c] = img
            out[i] += self.noise * rng.standard_normal((s, s, 3)).astype(np.float32)
        return np.clip(out, -1.0, 1.0)


class BlobFaces:
    """AFHQ stand-in: 32×32 'faces' with class-varying geometry/colors."""

    def __init__(self, size: int = 32, n_classes: int = 3, seed: int = 7, noise: float = 0.05):
        self.size = size
        self.noise = noise
        rng = np.random.default_rng(seed)
        self.classes = [
            {
                "bg": rng.uniform(-0.7, 0.7, size=3),
                "fur": rng.uniform(-0.3, 0.9, size=3),
                "eye_y": rng.uniform(0.3, 0.45),
                "eye_dx": rng.uniform(0.15, 0.25),
                "eye_r": rng.uniform(1.2, 2.5),
                "head_r": rng.uniform(0.32, 0.42),
            }
            for _ in range(n_classes)
        ]

    def batch(self, n: int, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        s = self.size
        yy, xx = np.mgrid[0:s, 0:s].astype(np.float32) / s
        out = np.zeros((n, s, s, 3), np.float32)
        cls = rng.integers(0, len(self.classes), size=n)
        for i in range(n):
            p = self.classes[cls[i]]
            cx = 0.5 + 0.05 * rng.standard_normal()
            cy = 0.55 + 0.05 * rng.standard_normal()
            head = np.exp(-(((xx - cx) ** 2 + (yy - cy) ** 2)) / (2 * p["head_r"] ** 2))
            img = np.zeros((s, s, 3), np.float32)
            for c in range(3):
                grad = p["bg"][c] + 0.3 * (yy - 0.5)
                img[:, :, c] = grad * (1 - head) + p["fur"][c] * head
            for sign in (-1, 1):
                ex = cx + sign * p["eye_dx"]
                ey = cy - p["eye_y"] * p["head_r"] * 2
                eye = np.exp(-(((xx - ex) ** 2 + (yy - ey) ** 2) * s * s) / (2 * p["eye_r"] ** 2))
                img -= 0.9 * eye[:, :, None]
            mouth = np.exp(-(((xx - cx) ** 2) * 60 + ((yy - cy - 0.12) ** 2) * 300))
            img -= 0.5 * mouth[:, :, None]
            img += self.noise * rng.standard_normal((s, s, 3)).astype(np.float32)
            out[i] = img
        return np.clip(out, -1.0, 1.0)


class BinaryDigits:
    """14×14 binary digit glyphs in {-1, +1} (MNIST stand-in for MAF)."""

    def __init__(self, size: int = 14, seed: int = 3):
        self.size = size
        self.seed = seed

    def batch(self, n: int, seed: int, dequant: float = 0.0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        s = self.size
        out = -np.ones((n, s, s), np.float32)
        digits = rng.integers(0, 10, size=n)
        for i in range(n):
            glyph = _FONT[int(digits[i])]
            # Scale the 3×5 glyph to ~9×12 with per-sample jitter.
            ox = int(rng.integers(2, 4))
            oy = int(rng.integers(1, 3))
            sx, sy = 3, 2
            for gy, row in enumerate(glyph):
                for gx, ch in enumerate(row):
                    if ch == "1":
                        y0, x0 = oy + gy * sx, ox + gx * sy + gx
                        out[i, y0:y0 + sx, x0:x0 + sy + 1] = 1.0
        flat = out.reshape(n, s * s)
        if dequant > 0:
            flat = flat + dequant * rng.standard_normal(flat.shape).astype(np.float32)
        return flat


def make_dataset(name: str):
    """Factory used by training and by the aot config."""
    if name == "synth10":
        return SynthImages(16, 10, seed=10)
    if name == "synth100":
        return SynthImages(16, 100, seed=100)
    if name == "synthafhq":
        return BlobFaces(32, 3, seed=7)
    if name == "digits":
        return BinaryDigits(14, seed=3)
    raise ValueError(f"unknown dataset '{name}'")
