//! Capacity model for the replica tier + device sharding, over the **mock
//! backend** — no artifacts needed, so it runs everywhere (including the
//! CI smoke step).
//!
//! Two phases:
//!
//! * **Saturation table** — a burst of `n` single-slot requests is pushed
//!   through every (replicas R, devices D) corner and drained to empty,
//!   yielding the req/s × replica-count vs p99 capacity table. On the mock,
//!   device ordinals are *placement* (the same stage threads mapped onto
//!   more ledgers), not extra silicon, so the honest `req/s per device`
//!   column divides by R × D — the table is the methodology artifact, the
//!   scaling *gate* is on replicas, which really do add decode parallelism.
//! * **Skewed-replica routing** — one replica decodes 32× slower. The
//!   least-loaded dispatch board (in-flight-weighted batcher pulls) must
//!   beat a static round-robin split of the same Poisson trace across two
//!   single-replica routers on p99: round-robin keeps feeding the slow
//!   replica and queues behind it; the board only hands it waves it can
//!   actually hold.
//!
//! Gates (exit non-zero on failure):
//! * every request in every run resolves with output **bit-identical** to
//!   its solo serial decode (τ = 0) — placement and routing never change
//!   math,
//! * R=2 drains the burst at ≥ 1.7× the R=1 throughput at comparable p99
//!   (≤ 1.25×),
//! * the D=2 run really shards: both ordinals' ledgers saw decode calls,
//! * least-loaded p99 < round-robin p99 under the skewed replica, with the
//!   fast replica handling more waves than the slow one.
//!
//! ```bash
//! cargo bench --bench capacity            # full run (96-request bursts)
//! cargo bench --bench capacity -- --quick # CI smoke (48-request bursts)
//! ```

use anyhow::Result;
use sjd::benchkit::Report;
use sjd::coordinator::batcher::Batcher;
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::router::{Router, RouterConfig};
use sjd::coordinator::sampler::{SampleOptions, Sampler};
use sjd::metrics::Registry;
use sjd::tensor::Pcg64;
use sjd::testkit::mockflow::{MockLedger, MockServeBackend};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Per-slot artificial decode cost (per jstep call, × batch size).
const SLOT_DELAY: Duration = Duration::from_micros(300);
/// Slow-replica multiplier for the skew scenarios.
const SLOW_FACTOR: u32 = 32;
/// Flow blocks in `MockFlow::standard()` (= stage count at `stage_threads: 0`).
const STAGES: usize = 4;
/// Distinct request seeds (kept small so solo references are cached).
const SEED_SPACE: u64 = 6;
/// Offered load for the skew phase (req/s) — past the slow replica's
/// capacity, well under the fast one's.
const SKEW_RPS: f64 = 80.0;

fn quick() -> bool {
    std::env::args().any(|a| a == "--quick") || std::env::var("SJD_QUICK").is_ok()
}

fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((sorted.len() as f64 - 1.0) * q) as usize]
}

fn opts() -> SampleOptions {
    let mut o = SampleOptions { policy: DecodePolicy::UniformJacobi, ..Default::default() };
    o.jacobi.tau = 0.0;
    o
}

/// Solo serial decode of one seed at bucket 1 — the bit-exactness oracle.
fn solo_reference(seed: u64) -> Result<Vec<f32>> {
    let be = MockServeBackend::new(&[1], Duration::ZERO, MockLedger::new());
    let sampler = Sampler::new(&be, "mock", 1)?;
    let z = sampler.sample_prior_slots(&[seed]);
    let out = sampler.decode_tokens(z, &opts())?;
    Ok(sampler.unpatchify(&out.tokens)?[0].data().to_vec())
}

/// One capacity-table corner.
#[derive(Clone, Copy)]
struct TierSpec {
    label: &'static str,
    replicas: usize,
    devices: usize,
    /// Worker index decoding `SLOW_FACTOR`× slower (skew scenarios).
    slow_widx: Option<usize>,
    /// Offered load in req/s; `0.0` = saturating burst (submit everything,
    /// measure the drain).
    rps: f64,
}

struct TierStats {
    spec: TierSpec,
    wall: Duration,
    ok: u64,
    exact: u64,
    latencies_ms: Vec<f64>,
    /// Decode (jstep) calls per device ordinal, summed over replicas.
    ord_jsteps: Vec<usize>,
    /// Decode (jstep) calls per worker/replica index, summed over ordinals.
    widx_jsteps: Vec<usize>,
}

impl TierStats {
    fn throughput(&self) -> f64 {
        self.ok as f64 / self.wall.as_secs_f64()
    }

    fn p50(&self) -> f64 {
        pct(&self.latencies_ms, 0.50)
    }

    fn p99(&self) -> f64 {
        pct(&self.latencies_ms, 0.99)
    }

    fn devices_used(&self) -> usize {
        self.spec.replicas.max(1) * self.spec.devices.clamp(1, STAGES)
    }
}

/// Submit `n_requests` single-slot requests (Poisson at `spec.rps`, or all
/// at once when it's 0) against one router built per `spec`, wait for every
/// slot, and collect latency + bit-exactness + per-ledger routing evidence.
fn run_tier(spec: TierSpec, n_requests: usize, solo: &Arc<Vec<Vec<f32>>>) -> Result<TierStats> {
    let registry = Registry::new();
    let batcher = Batcher::new(1, Duration::from_micros(500));
    let nworkers = spec.replicas.max(1);
    // One ledger per (worker, ordinal): rows prove replica routing, columns
    // prove device placement.
    let ledgers: Vec<Vec<Arc<MockLedger>>> =
        (0..nworkers).map(|_| (0..STAGES).map(|_| MockLedger::new()).collect()).collect();
    let lgs = ledgers.clone();
    let router = Router::start_with_devices(
        RouterConfig {
            artifacts_dir: "mock".into(),
            model: "mock".into(),
            buckets: Vec::new(),
            workers: 1,
            options: opts(),
            pipeline_depth: 2,
            stage_threads: 0,
            refill: false,
            tuner: None,
            warm_cap: 0,
            governor: None,
            fault: Default::default(),
            replicas: spec.replicas,
            devices: spec.devices,
        },
        batcher.clone(),
        registry.clone(),
        move |widx, ordinal| {
            let delay =
                if spec.slow_widx == Some(widx) { SLOT_DELAY * SLOW_FACTOR } else { SLOT_DELAY };
            Ok(MockServeBackend::new(&[1], delay, lgs[widx][ordinal].clone())
                .on_ordinal(ordinal))
        },
    )?;

    let lat = Arc::new(Mutex::new(Vec::new()));
    let ok = Arc::new(AtomicU64::new(0));
    let exact = Arc::new(AtomicU64::new(0));
    let mut rng = Pcg64::seed(4242);
    let t0 = Instant::now();
    let mut waiters = Vec::new();
    for i in 0..n_requests {
        if spec.rps > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(rng.next_exp() / spec.rps));
        }
        let seed = i as u64 % SEED_SPACE;
        let submitted = Instant::now();
        let h = batcher.submit_slot(i as u64, seed)?;
        let (lat, ok, exact, solo) = (lat.clone(), ok.clone(), exact.clone(), solo.clone());
        waiters.push(std::thread::spawn(move || {
            if let Some(Ok(img)) = h.done.wait_timeout(Duration::from_secs(120)) {
                ok.fetch_add(1, Ordering::SeqCst);
                if img.data() == &solo[seed as usize][..] {
                    exact.fetch_add(1, Ordering::SeqCst);
                }
            }
            lat.lock().unwrap().push(submitted.elapsed().as_secs_f64() * 1e3);
        }));
    }
    for w in waiters {
        let _ = w.join();
    }
    let wall = t0.elapsed();
    router.shutdown();

    let mut latencies = lat.lock().unwrap().clone();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let ord_jsteps = (0..STAGES)
        .map(|ord| ledgers.iter().map(|per_w| per_w[ord].count_containing("_jstep")).sum())
        .collect();
    let widx_jsteps = ledgers
        .iter()
        .map(|per_w| per_w.iter().map(|l| l.count_containing("_jstep")).sum())
        .collect();
    Ok(TierStats {
        spec,
        wall,
        ok: ok.load(Ordering::SeqCst),
        exact: exact.load(Ordering::SeqCst),
        latencies_ms: latencies,
        ord_jsteps,
        widx_jsteps,
    })
}

/// The round-robin strawman for the skew phase: the same Poisson trace
/// split i%2 across two *independent* single-replica routers (separate
/// batchers — no shared queue, no board), worker 0 slow. This is what
/// static per-replica assignment would do.
fn run_round_robin(n_requests: usize, solo: &Arc<Vec<Vec<f32>>>) -> Result<TierStats> {
    let mut routers = Vec::new();
    let mut batchers = Vec::new();
    for widx in 0..2usize {
        let registry = Registry::new();
        let batcher = Batcher::new(1, Duration::from_micros(500));
        let delay = if widx == 0 { SLOT_DELAY * SLOW_FACTOR } else { SLOT_DELAY };
        routers.push(Router::start_with(
            RouterConfig {
                artifacts_dir: "mock".into(),
                model: "mock".into(),
                buckets: Vec::new(),
                workers: 1,
                options: opts(),
                pipeline_depth: 2,
                stage_threads: 0,
                refill: false,
                tuner: None,
                warm_cap: 0,
                governor: None,
                fault: Default::default(),
                replicas: 1,
                devices: 1,
            },
            batcher.clone(),
            registry,
            move |_| Ok(MockServeBackend::new(&[1], delay, MockLedger::new())),
        )?);
        batchers.push(batcher);
    }

    let lat = Arc::new(Mutex::new(Vec::new()));
    let ok = Arc::new(AtomicU64::new(0));
    let exact = Arc::new(AtomicU64::new(0));
    let mut rng = Pcg64::seed(4242);
    let t0 = Instant::now();
    let mut waiters = Vec::new();
    for i in 0..n_requests {
        std::thread::sleep(Duration::from_secs_f64(rng.next_exp() / SKEW_RPS));
        let seed = i as u64 % SEED_SPACE;
        let submitted = Instant::now();
        let h = batchers[i % 2].submit_slot(i as u64, seed)?;
        let (lat, ok, exact, solo) = (lat.clone(), ok.clone(), exact.clone(), solo.clone());
        waiters.push(std::thread::spawn(move || {
            if let Some(Ok(img)) = h.done.wait_timeout(Duration::from_secs(120)) {
                ok.fetch_add(1, Ordering::SeqCst);
                if img.data() == &solo[seed as usize][..] {
                    exact.fetch_add(1, Ordering::SeqCst);
                }
            }
            lat.lock().unwrap().push(submitted.elapsed().as_secs_f64() * 1e3);
        }));
    }
    for w in waiters {
        let _ = w.join();
    }
    let wall = t0.elapsed();
    for r in routers {
        r.shutdown();
    }

    let mut latencies = lat.lock().unwrap().clone();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(TierStats {
        spec: TierSpec {
            label: "round-robin R=2 skewed",
            replicas: 2,
            devices: 1,
            slow_widx: Some(0),
            rps: SKEW_RPS,
        },
        wall,
        ok: ok.load(Ordering::SeqCst),
        exact: exact.load(Ordering::SeqCst),
        latencies_ms: latencies,
        ord_jsteps: Vec::new(),
        widx_jsteps: Vec::new(),
    })
}

fn row(s: &TierStats) -> Vec<String> {
    vec![
        s.spec.label.to_string(),
        s.spec.replicas.to_string(),
        s.spec.devices.to_string(),
        format!("{:.2}", s.wall.as_secs_f64()),
        format!("{:.1}", s.throughput()),
        format!("{:.1}", s.throughput() / s.devices_used() as f64),
        format!("{:.1}", s.p50()),
        format!("{:.1}", s.p99()),
    ]
}

fn main() -> Result<()> {
    let n = if quick() { 48 } else { 96 };
    let n_skew = if quick() { 40 } else { 60 };
    println!(
        "=== capacity: {n}-request saturation bursts × (replicas, devices), then \
         {n_skew} requests at {SKEW_RPS} req/s with one {SLOW_FACTOR}× slow replica \
         (mock backend) ==="
    );
    let mut report = Report::new("Capacity model — replica tier × device sharding");

    let solo: Arc<Vec<Vec<f32>>> =
        Arc::new((0..SEED_SPACE).map(solo_reference).collect::<Result<_>>()?);

    let corners = [
        TierSpec { label: "R=1 D=1", replicas: 1, devices: 1, slow_widx: None, rps: 0.0 },
        TierSpec { label: "R=2 D=1", replicas: 2, devices: 1, slow_widx: None, rps: 0.0 },
        TierSpec { label: "R=1 D=2", replicas: 1, devices: 2, slow_widx: None, rps: 0.0 },
        TierSpec { label: "R=2 D=2", replicas: 2, devices: 2, slow_widx: None, rps: 0.0 },
    ];
    let mut tiers = Vec::new();
    for spec in corners {
        let s = run_tier(spec, n, &solo)?;
        println!(
            "[{}] {} ok / {n} in {:.2}s → {:.1} req/s ({:.1}/device) | ms p50 {:.1} p99 {:.1}",
            s.spec.label,
            s.ok,
            s.wall.as_secs_f64(),
            s.throughput(),
            s.throughput() / s.devices_used() as f64,
            s.p50(),
            s.p99(),
        );
        tiers.push(s);
    }

    let ll = run_tier(
        TierSpec {
            label: "least-loaded R=2 skewed",
            replicas: 2,
            devices: 1,
            slow_widx: Some(0),
            rps: SKEW_RPS,
        },
        n_skew,
        &solo,
    )?;
    let rr = run_round_robin(n_skew, &solo)?;
    for s in [&ll, &rr] {
        println!(
            "[{}] {} ok / {n_skew} in {:.2}s | ms p50 {:.1} p99 {:.1}",
            s.spec.label,
            s.ok,
            s.wall.as_secs_f64(),
            s.p50(),
            s.p99(),
        );
    }
    println!(
        "least-loaded wave split: slow replica {} jsteps, fast replica {} jsteps",
        ll.widx_jsteps[0], ll.widx_jsteps[1]
    );

    report.table(
        &["config", "R", "D", "wall (s)", "req/s", "req/s per device", "p50 (ms)", "p99 (ms)"],
        &tiers.iter().chain([&ll, &rr]).map(row).collect::<Vec<_>>(),
    );

    // Gates.
    let exact_everywhere = tiers
        .iter()
        .chain([&ll, &rr])
        .all(|s| s.ok == s.exact && s.ok == s.latencies_ms.len() as u64 && s.ok > 0);
    let thr_gain = tiers[1].throughput() / tiers[0].throughput();
    let p99_ratio = tiers[1].p99() / tiers[0].p99().max(1e-9);
    let replicas_scale = thr_gain >= 1.7 && p99_ratio <= 1.25;
    let sharded = tiers[2].ord_jsteps[0] > 0 && tiers[2].ord_jsteps[1] > 0;
    let routing_wins = ll.p99() < rr.p99() && ll.widx_jsteps[1] > ll.widx_jsteps[0];

    println!("\n=== summary ===");
    println!(
        "R=1→R=2 throughput ×{thr_gain:.2} (gate ≥1.7) at p99 ratio {p99_ratio:.2} (gate ≤1.25) \
         | D=2 ordinal jsteps {:?} | skew p99: least-loaded {:.1} ms vs round-robin {:.1} ms",
        &tiers[2].ord_jsteps[..2],
        ll.p99(),
        rr.p99(),
    );
    report.note(format!(
        "replica scaling ×{thr_gain:.2} at p99 ratio {p99_ratio:.2}; least-loaded p99 \
         {:.1} ms vs round-robin {:.1} ms under a {SLOW_FACTOR}× slow replica; every \
         output bit-exact with its solo decode: {exact_everywhere}",
        ll.p99(),
        rr.p99(),
    ));
    report.note(if replicas_scale && sharded && routing_wins && exact_everywhere {
        "PASS: replicas buy ≥1.7× saturation throughput at comparable p99, spans really \
         shard across ordinals, and least-loaded dispatch beats round-robin under skew."
    } else {
        "FAIL: the replica tier must scale throughput, shard spans, and out-route \
         round-robin without changing a single output bit."
    });
    report.finish();

    if replicas_scale && sharded && routing_wins && exact_everywhere {
        println!("PASS: capacity gates hold");
        Ok(())
    } else {
        println!(
            "FAIL: exact={exact_everywhere} replicas_scale={replicas_scale} (×{thr_gain:.2}, \
             p99 {p99_ratio:.2}) sharded={sharded} routing_wins={routing_wins}"
        );
        std::process::exit(1);
    }
}
