//! Deterministic RNG: PCG64 (XSL-RR variant) + cached Box–Muller gaussians.
//!
//! Prior noise `z_K ~ N(0, I)` is drawn in rust on the request path, so the
//! generator must be fast, seedable, and reproducible across runs.

/// PCG64 XSL-RR generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    cached_gauss: Option<f32>,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Seed with a stream derived from the seed itself.
    pub fn seed(seed: u64) -> Self {
        Self::seed_stream(seed, 0xda3e39cb94b95bdb)
    }

    pub fn seed_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
            cached_gauss: None,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        // 24 high bits → exactly representable in f32.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Modulo bias negligible for n << 2^64 (we use n ≤ millions).
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (caches the second variate).
    pub fn next_gaussian(&mut self) -> f32 {
        if let Some(g) = self.cached_gauss.take() {
            return g;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.cached_gauss = Some((r * theta.sin()) as f32);
            return (r * theta.cos()) as f32;
        }
    }

    /// Exponential with rate 1 (used for Poisson arrival load generation).
    pub fn next_exp(&mut self) -> f64 {
        -(1.0 - self.next_f64()).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::seed(7);
        let mut b = Pcg64::seed(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed(1);
        let mut b = Pcg64::seed(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut rng = Pcg64::seed(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn gaussian_tail_fraction() {
        let mut rng = Pcg64::seed(9);
        let n = 20_000;
        let beyond2 = (0..n).filter(|_| rng.next_gaussian().abs() > 2.0).count();
        let frac = beyond2 as f64 / n as f64;
        // P(|Z| > 2) ≈ 0.0455
        assert!((frac - 0.0455).abs() < 0.01, "frac {frac}");
    }

    #[test]
    fn exp_mean() {
        let mut rng = Pcg64::seed(11);
        let mean: f64 = (0..20_000).map(|_| rng.next_exp()).sum::<f64>() / 20_000.0;
        assert!((mean - 1.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn next_below_bounds() {
        let mut rng = Pcg64::seed(13);
        for _ in 0..1000 {
            assert!(rng.next_below(7) < 7);
        }
    }
}
