//! JSON emitter (pretty-printed, deterministic key order).

use super::Value;
use std::fmt::Write as _;

/// Serialize with 2-space indentation.
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(&mut out, v, 0);
    out
}

fn write_value(out: &mut String, v: &Value, indent: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => write_num(out, *n),
        Value::Str(s) => write_str(out, s),
        Value::Arr(a) => {
            if a.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                pad(out, indent + 1);
                write_value(out, item, indent + 1);
            }
            out.push('\n');
            pad(out, indent);
            out.push(']');
        }
        Value::Obj(o) => {
            if o.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                pad(out, indent + 1);
                write_str(out, k);
                out.push_str(": ");
                write_value(out, val, indent + 1);
            }
            out.push('\n');
            pad(out, indent);
            out.push('}');
        }
    }
}

fn pad(out: &mut String, indent: usize) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}
