//! Coordinator unit tests over a **mock backend** — an analytically
//! invertible autoregressive flow implemented in pure rust, exposing the
//! same artifact ABI the real engine serves. Lets us test decode logic
//! (policy routing, permutations, Jacobi semantics, trace accounting)
//! hermetically, without artifacts or PJRT.
//!
//! Mock flow per block k (AR domain), with coupling strength a_k:
//!   forward: v_0 = u_0;  v_l = u_l − a_k · mean(u_{<l})
//!   inverse: u_l = v_l + a_k · mean(u_{<l})   (triangular ⇒ Jacobi applies)

use sjd::coordinator::jacobi::{jacobi_decode_block, JacobiConfig};
use sjd::coordinator::policy::DecodePolicy;
use sjd::coordinator::sampler::{SampleOptions, Sampler};
use sjd::runtime::{Backend, HostTensor, ModelMeta};
use sjd::tensor::Pcg64;
use std::collections::BTreeMap;

const K: usize = 4;
const L: usize = 8;
const D: usize = 3;
const NL: usize = 1;
const DM: usize = 4;

struct MockFlow {
    /// Per-block coupling strengths (index = block k).
    a: [f32; K],
}

impl MockFlow {
    fn new() -> Self {
        MockFlow { a: [0.9, 0.2, 0.15, 0.6] }
    }

    /// s,g conditioner: g_l = a_k · mean over tokens < l (per-dim), s = 0.
    fn g_at(&self, k: usize, z: &[f32], b: usize, l_idx: usize) -> Vec<f32> {
        let a = self.a[k];
        let mut g = vec![0.0f32; D];
        if l_idx == 0 {
            return g;
        }
        for li in 0..l_idx {
            for di in 0..D {
                g[di] += z[(b * L + li) * D + di];
            }
        }
        for gi in g.iter_mut() {
            *gi = a * *gi / l_idx as f32;
        }
        g
    }

    fn fwd(&self, k: usize, u: &[f32], batch: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; u.len()];
        for b in 0..batch {
            for l in 0..L {
                let g = self.g_at(k, u, b, l);
                for di in 0..D {
                    let idx = (b * L + l) * D + di;
                    v[idx] = u[idx] - g[di];
                }
            }
        }
        v
    }

    /// One Jacobi update of the inverse system (masked variant shifts the
    /// prefix bound like eq 6).
    fn jstep(&self, k: usize, z: &[f32], y: &[f32], o: usize, batch: usize) -> (Vec<f32>, Vec<f32>) {
        let mut z_next = vec![0.0f32; z.len()];
        let mut resid = vec![0.0f32; batch];
        for b in 0..batch {
            for l in 0..L {
                let bound = l.saturating_sub(o);
                let g = if l == 0 { vec![0.0; D] } else { self.g_at_masked(k, z, b, l, bound) };
                for di in 0..D {
                    let idx = (b * L + l) * D + di;
                    z_next[idx] = if l == 0 { y[idx] } else { y[idx] + g[di] };
                    resid[b] = resid[b].max((z_next[idx] - z[idx]).abs());
                }
            }
        }
        (z_next, resid)
    }

    fn g_at_masked(&self, k: usize, z: &[f32], b: usize, l_idx: usize, bound: usize) -> Vec<f32> {
        let a = self.a[k];
        let mut g = vec![0.0f32; D];
        let n = bound.max(1);
        for li in 0..bound.max(1).min(l_idx) {
            for di in 0..D {
                g[di] += z[(b * L + li) * D + di];
            }
        }
        for gi in g.iter_mut() {
            *gi = a * *gi / n as f32;
        }
        g
    }
}

/// Backend serving the mock flow under the standard artifact names.
struct MockBackend {
    flow: MockFlow,
    calls: std::cell::RefCell<BTreeMap<String, usize>>,
}

impl MockBackend {
    fn new() -> Self {
        MockBackend { flow: MockFlow::new(), calls: Default::default() }
    }

    fn count(&self, name: &str) -> usize {
        self.calls.borrow().get(name).copied().unwrap_or(0)
    }
}

impl Backend for MockBackend {
    fn call(&self, name: &str, inputs: &[HostTensor]) -> anyhow::Result<Vec<HostTensor>> {
        *self.calls.borrow_mut().entry(name.to_string()).or_default() += 1;
        let batch = 2usize;
        if name.contains("block_jstep") {
            let k = inputs[0].as_i32()?[0] as usize;
            let z = inputs[1].as_f32()?;
            let y = inputs[2].as_f32()?;
            let o = inputs[3].as_i32()?[0] as usize;
            let (zn, r) = self.flow.jstep(k, z, y, o, batch);
            Ok(vec![
                HostTensor::f32(inputs[1].shape(), zn),
                HostTensor::f32(&[batch], r),
            ])
        } else if name.contains("block_fwd") {
            let k = inputs[0].as_i32()?[0] as usize;
            let u = inputs[1].as_f32()?;
            Ok(vec![HostTensor::f32(inputs[1].shape(), self.flow.fwd(k, u, batch))])
        } else if name.contains("block_seqstep") {
            // Sequential step: maintain decoded prefix in the kv_k cache
            // (slot [0, b, pos, 0..D]), mirroring the real cache contract.
            let k = inputs[0].as_i32()?[0] as usize;
            let u_prev = inputs[1].as_f32()?;
            let v_tok = inputs[2].as_f32()?;
            let pos = inputs[3].as_i32()?[0] as usize;
            let mut kv_k = inputs[4].as_f32()?.to_vec();
            let kv_v = inputs[5].as_f32()?.to_vec();
            // Write u_prev (token at net position pos, i.e. u_{pos-1}) into
            // the cache at pos-1.
            if pos > 0 {
                for b in 0..batch {
                    for di in 0..D {
                        kv_k[(b * L + (pos - 1)) * DM + di] = u_prev[b * D + di];
                    }
                }
            }
            // u_pos = v_pos + g(prefix) with prefix read from the cache.
            let mut u_tok = vec![0.0f32; batch * D];
            for b in 0..batch {
                if pos == 0 {
                    u_tok[b * D..(b + 1) * D].copy_from_slice(&v_tok[b * D..(b + 1) * D]);
                } else {
                    let a = self.flow.a[k];
                    for di in 0..D {
                        let mut g = 0.0;
                        for li in 0..pos {
                            g += kv_k[(b * L + li) * DM + di];
                        }
                        u_tok[b * D + di] = v_tok[b * D + di] + a * g / pos as f32;
                    }
                }
            }
            Ok(vec![
                HostTensor::f32(&[batch, D], u_tok),
                HostTensor::f32(inputs[4].shape(), kv_k),
                HostTensor::f32(inputs[5].shape(), kv_v),
            ])
        } else {
            anyhow::bail!("mock backend: unknown artifact '{name}'")
        }
    }

    fn model_meta(&self, model: &str) -> anyhow::Result<ModelMeta> {
        Ok(ModelMeta {
            name: model.to_string(),
            kind: "tarflow".into(),
            seq_len: L,
            blocks: K,
            token_dim: D,
            model_dim: DM,
            layers_per_block: NL,
            image_hwc: Some([4, 6, 1]), // 4×6×1 → (4/2)·(6/2) = 6... use patch 1
            patch: 1,
            noise_std: 0.0,
            batch_sizes: vec![2],
            extra: BTreeMap::new(),
        })
    }
}

fn mk_sampler(backend: &MockBackend) -> Sampler<'_, MockBackend> {
    Sampler::new(backend, "mock", 2).expect("mock sampler")
}

fn randn(shape: &[usize], seed: u64) -> HostTensor {
    let mut rng = Pcg64::seed(seed);
    HostTensor::f32(shape, (0..shape.iter().product()).map(|_| rng.next_gaussian()).collect())
}

#[test]
fn jacobi_converges_to_mock_inverse() {
    let be = MockBackend::new();
    let u = randn(&[2, L, D], 1);
    let v_vec = be.flow.fwd(2, u.as_f32().unwrap(), 2);
    let v = HostTensor::f32(&[2, L, D], v_vec);
    let cfg = JacobiConfig { tau: 1e-6, ..Default::default() };
    let (u_rec, stats) = jacobi_decode_block(&be, "mock_block_jstep_b2", 2, &v, L, &cfg, 0).unwrap();
    let err = u
        .as_f32()
        .unwrap()
        .iter()
        .zip(u_rec.as_f32().unwrap())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(err < 1e-4, "err {err}");
    assert!(stats.iterations <= L);
    assert!(stats.converged);
    // Residuals strictly decreasing for this linear triangular system.
    for w in stats.residuals.windows(2) {
        assert!(w[1] <= w[0] + 1e-6, "{:?}", stats.residuals);
    }
}

#[test]
fn weak_coupling_converges_faster_than_strong() {
    // Blocks differ only in coupling strength a_k: stronger coupling ⇒ more
    // iterations (the paper's redundancy heterogeneity, distilled).
    let be = MockBackend::new();
    let y = randn(&[2, L, D], 2);
    let cfg = JacobiConfig { tau: 1e-4, ..Default::default() };
    let (_, strong) = jacobi_decode_block(&be, "m_block_jstep", 0, &y, L, &cfg, 0).unwrap(); // a=0.9
    let (_, weak) = jacobi_decode_block(&be, "m_block_jstep", 2, &y, L, &cfg, 0).unwrap(); // a=0.15
    assert!(
        weak.iterations < strong.iterations,
        "weak {} vs strong {}",
        weak.iterations,
        strong.iterations
    );
}

#[test]
fn sequential_decode_matches_jacobi_fixed_point() {
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let u = randn(&[2, L, D], 3);
    let v_vec = be.flow.fwd(1, u.as_f32().unwrap(), 2);
    let v = HostTensor::f32(&[2, L, D], v_vec);
    let (u_seq, steps) = sampler.sequential_decode_block(1, &v).unwrap();
    assert_eq!(steps, L);
    let err = u
        .as_f32()
        .unwrap()
        .iter()
        .zip(u_seq.as_f32().unwrap())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(err < 1e-4, "sequential inverse error {err}");
}

#[test]
fn policy_routes_blocks_correctly() {
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let z = randn(&[2, L, D], 4);
    let opts = SampleOptions {
        policy: DecodePolicy::Selective { seq_blocks: 1 },
        ..Default::default()
    };
    let out = sampler.decode_tokens(z, &opts).unwrap();
    assert_eq!(out.traces.len(), K);
    assert!(!out.traces[0].used_jacobi, "first decode position must be sequential");
    for t in &out.traces[1..] {
        assert!(t.used_jacobi);
    }
    // Sequential position consumed exactly L seqstep calls.
    assert_eq!(be.count("mock_block_seqstep_b2"), L);
    // Block indices run K-1 .. 0.
    let blocks: Vec<usize> = out.traces.iter().map(|t| t.block).collect();
    assert_eq!(blocks, vec![3, 2, 1, 0]);
}

#[test]
fn uniform_jacobi_never_calls_seqstep() {
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let z = randn(&[2, L, D], 5);
    let opts = SampleOptions { policy: DecodePolicy::UniformJacobi, ..Default::default() };
    let _ = sampler.decode_tokens(z, &opts).unwrap();
    assert_eq!(be.count("mock_block_seqstep_b2"), 0);
    assert!(be.count("mock_block_jstep_b2") >= K);
}

#[test]
fn decode_then_encode_is_identity() {
    // Full decode (all policies exact) followed by the rust-composed forward
    // must reproduce the prior — validates permutation handling end to end
    // against the mock flow.
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let z0 = randn(&[2, L, D], 6);
    let mut opts = SampleOptions { policy: DecodePolicy::UniformJacobi, ..Default::default() };
    opts.jacobi.tau = 1e-7;
    let out = sampler.decode_tokens(z0.clone(), &opts).unwrap();

    // Re-encode: h_{k+1} = A_k(P_k h_k).
    let mut h = out.tokens;
    for k in 0..K {
        let u = if k % 2 == 1 { sampler.reverse_tokens(&h).unwrap() } else { h };
        h = sampler.block_forward(k, &u).unwrap();
    }
    let err = z0
        .as_f32()
        .unwrap()
        .iter()
        .zip(h.as_f32().unwrap())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(err < 1e-3, "decode∘encode identity error {err}");
}

#[test]
fn masked_decode_deviates_more_with_larger_o() {
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let u = randn(&[2, L, D], 7);
    let v = HostTensor::f32(&[2, L, D], be.flow.fwd(0, u.as_f32().unwrap(), 2));
    let cfg = JacobiConfig { tau: 1e-7, ..Default::default() };
    let mut errs = Vec::new();
    for o in [0usize, 2, 5] {
        let (u_rec, _) = sampler.jacobi_decode(0, &v, &cfg, o).unwrap();
        let err: f32 = u
            .as_f32()
            .unwrap()
            .iter()
            .zip(u_rec.as_f32().unwrap())
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            .sqrt();
        errs.push(err);
    }
    assert!(errs[0] < 1e-3, "o=0 must be exact: {errs:?}");
    assert!(errs[1] > errs[0] && errs[2] > errs[1], "monotone in o: {errs:?}");
}

#[test]
fn trace_accounting_sums() {
    let be = MockBackend::new();
    let sampler = mk_sampler(&be);
    let z = randn(&[2, L, D], 8);
    let out = sampler.decode_tokens(z, &SampleOptions::default()).unwrap();
    let jacobi_iters: usize =
        out.traces.iter().filter(|t| t.used_jacobi).map(|t| t.steps).sum();
    assert_eq!(out.total_jacobi_iters(), jacobi_iters);
    assert_eq!(be.count("mock_block_jstep_b2"), jacobi_iters);
    let decode_total: std::time::Duration = out.traces.iter().map(|t| t.wall).sum();
    assert!(out.total_wall >= decode_total);
}

#[test]
fn max_iters_cap_respected() {
    let be = MockBackend::new();
    let y = randn(&[2, L, D], 9);
    let cfg = JacobiConfig { tau: 0.0, max_iters: Some(3), ..Default::default() };
    let (_, stats) = jacobi_decode_block(&be, "m_block_jstep", 0, &y, L, &cfg, 0).unwrap();
    assert_eq!(stats.iterations, 3);
    assert!(!stats.converged);
}
