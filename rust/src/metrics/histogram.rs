//! Log-bucketed histogram for latency/duration measurements.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of buckets: values are bucketed by `log2` with 4 sub-buckets per
/// octave, covering ~1 ns to ~18 s of nanosecond measurements.
const SUB_BUCKETS: usize = 4;
const OCTAVES: usize = 35;
const NUM_BUCKETS: usize = OCTAVES * SUB_BUCKETS;

/// Lock-free histogram of u64 samples (typically nanoseconds).
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    fn bucket_index(v: u64) -> usize {
        if v == 0 {
            return 0;
        }
        let octave = 63 - v.leading_zeros() as usize; // floor(log2 v)
        let base = 1u64 << octave;
        // Sub-bucket from the next bits.
        let sub = (((v - base) * SUB_BUCKETS as u64) / base.max(1)) as usize;
        (octave * SUB_BUCKETS + sub.min(SUB_BUCKETS - 1)).min(NUM_BUCKETS - 1)
    }

    /// Lower bound of a bucket (inverse of `bucket_index`).
    fn bucket_floor(idx: usize) -> u64 {
        let octave = idx / SUB_BUCKETS;
        let sub = idx % SUB_BUCKETS;
        let base = 1u64 << octave;
        base + (base / SUB_BUCKETS as u64) * sub as u64
    }

    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn record_duration(&self, d: std::time::Duration) {
        self.record(d.as_nanos().min(u128::from(u64::MAX)) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Consistent-enough snapshot for reporting.
    pub fn snapshot(&self) -> Snapshot {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        Snapshot {
            counts,
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time view of a [`Histogram`] with percentile queries.
#[derive(Clone, Debug)]
pub struct Snapshot {
    counts: Vec<u64>,
    pub count: u64,
    pub sum: u64,
    pub max: u64,
}

impl Snapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Approximate quantile (bucket lower bound), q in [0, 1].
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((self.count as f64) * q).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Histogram::bucket_floor(i);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty() {
        let h = Histogram::new();
        let s = h.snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.quantile(0.5), 0);
        assert_eq!(s.mean(), 0.0);
    }

    #[test]
    fn bucket_floor_inverts_index() {
        for v in [1u64, 2, 3, 5, 100, 1023, 1024, 1_000_000, u32::MAX as u64] {
            let idx = Histogram::bucket_index(v);
            let floor = Histogram::bucket_floor(idx);
            assert!(floor <= v, "floor {floor} > v {v}");
            // Bucket width is ≤ base/SUB_BUCKETS + rounding; floor within 2× of v.
            assert!(v < floor * 2 + 2, "v {v} too far above floor {floor}");
        }
    }

    #[test]
    fn quantiles_ordered() {
        let h = Histogram::new();
        for i in 1..=10_000u64 {
            h.record(i);
        }
        let s = h.snapshot();
        assert!(s.p50() <= s.p95());
        assert!(s.p95() <= s.p99());
        assert!(s.p99() <= s.max);
        // p50 of uniform 1..10000 ≈ 5000; log buckets are coarse (≤ 25%).
        let p50 = s.p50() as f64;
        assert!((3800.0..6200.0).contains(&p50), "p50 {p50}");
    }

    #[test]
    fn mean_exact() {
        let h = Histogram::new();
        for v in [10u64, 20, 30] {
            h.record(v);
        }
        assert_eq!(h.snapshot().mean(), 20.0);
        assert_eq!(h.snapshot().max, 30);
        assert_eq!(h.count(), 3);
    }

    #[test]
    fn concurrent_recording() {
        let h = std::sync::Arc::new(Histogram::new());
        let mut handles = Vec::new();
        for t in 0..4 {
            let h = h.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    h.record(t * 1000 + i);
                }
            }));
        }
        for j in handles {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 4000);
    }
}
